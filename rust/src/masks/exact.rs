//! Exact transposable N:M mask solver via min-cost flow.
//!
//! Problem (2) is a transportation problem on the bipartite graph
//! rows -> cols: every row ships N units, every column receives N units,
//! each cell carries at most 1 unit, and we maximize the shipped score.
//! The LP relaxation is integral (b-matching polytope), so min-cost flow
//! returns the true binary optimum f(S*) used as the reference in Fig. 3,
//! Fig. 6 and the error columns of the bench reports. This plays the role
//! of the paper's "Network Flow" method (Hubara et al. 2021) and of
//! Gurobi as the optimality oracle.
//!
//! Implementation: successive shortest augmenting paths with Johnson
//! potentials (Dijkstra on dense adjacency — the graph has 2M+2 nodes, so
//! dense scan beats a heap for M <= 32). Costs are shifted to
//! `max_score - score >= 0` so initial potentials are zero.

use crate::util::tensor::{Blocks, BlocksView};

/// Solve one M x M block exactly. Returns (mask, objective).
pub fn solve_block(score: &[f32], m: usize, n: usize) -> (Vec<f32>, f64) {
    debug_assert_eq!(score.len(), m * m);
    if n == 0 {
        return (vec![0.0; m * m], 0.0);
    }
    if n == m {
        let obj = score.iter().map(|&x| x as f64).sum();
        return (vec![1.0; m * m], obj);
    }

    // Node ids: 0 = source, 1..=m rows, m+1..=2m cols, 2m+1 sink.
    let nodes = 2 * m + 2;
    let source = 0usize;
    let sink = 2 * m + 1;

    let max_score = score.iter().fold(0.0f32, |a, &x| a.max(x)) as f64;
    // cell cost (nonneg): shifting by max_score keeps argmax unchanged
    // because every feasible solution selects exactly n*m cells.
    let cell_cost = |i: usize, j: usize| -> f64 { max_score - score[i * m + j] as f64 };

    // Flow state: cap/flow on source->row and col->sink as vectors;
    // row->col as an m x m 0/1 flow matrix.
    let mut src_flow = vec![0usize; m];
    let mut snk_flow = vec![0usize; m];
    let mut cell_flow = vec![false; m * m];
    let mut potential = vec![0.0f64; nodes];

    let total = n * m;
    for _ in 0..total {
        // Dijkstra with reduced costs from source.
        let inf = f64::INFINITY;
        let mut dist = vec![inf; nodes];
        let mut prev = vec![usize::MAX; nodes];
        let mut done = vec![false; nodes];
        dist[source] = 0.0;
        loop {
            let mut u = usize::MAX;
            let mut best = inf;
            for v in 0..nodes {
                if !done[v] && dist[v] < best {
                    best = dist[v];
                    u = v;
                }
            }
            if u == usize::MAX || u == sink {
                break;
            }
            done[u] = true;
            let du = dist[u];
            if u == source {
                for i in 0..m {
                    if src_flow[i] < n {
                        let nd = du + potential[source] - potential[1 + i];
                        if nd < dist[1 + i] {
                            dist[1 + i] = nd;
                            prev[1 + i] = source;
                        }
                    }
                }
            } else if u >= 1 && u <= m {
                let i = u - 1;
                // forward edges to columns with no flow
                for j in 0..m {
                    if !cell_flow[i * m + j] {
                        let v = m + 1 + j;
                        let nd = du + cell_cost(i, j) + potential[u] - potential[v];
                        if nd < dist[v] {
                            dist[v] = nd;
                            prev[v] = u;
                        }
                    }
                }
                // backward edge to source if flow exists
                if src_flow[i] > 0 {
                    let nd = du + potential[u] - potential[source];
                    if nd < dist[source] {
                        dist[source] = nd;
                        prev[source] = u;
                    }
                }
            } else if u >= m + 1 && u <= 2 * m {
                let j = u - m - 1;
                // forward to sink
                if snk_flow[j] < n {
                    let nd = du + potential[u] - potential[sink];
                    if nd < dist[sink] {
                        dist[sink] = nd;
                        prev[sink] = u;
                    }
                }
                // backward edges to rows with flow (residual, negated cost)
                for i in 0..m {
                    if cell_flow[i * m + j] {
                        let v = 1 + i;
                        let nd = du - cell_cost(i, j) + potential[u] - potential[v];
                        if nd < dist[v] {
                            dist[v] = nd;
                            prev[v] = u;
                        }
                    }
                }
            }
        }
        debug_assert!(dist[sink].is_finite(), "no augmenting path");
        // Update potentials (cap at dist[sink] so reduced costs stay
        // nonnegative for nodes settled after the early exit).
        let dsink = dist[sink];
        for v in 0..nodes {
            potential[v] += dist[v].min(dsink);
        }
        // Trace back and push one unit.
        let mut v = sink;
        while v != source {
            let u = prev[v];
            debug_assert_ne!(u, usize::MAX);
            if u >= 1 && u <= m && v >= m + 1 && v <= 2 * m {
                cell_flow[(u - 1) * m + (v - m - 1)] = true;
            } else if v >= 1 && v <= m && u >= m + 1 && u <= 2 * m {
                cell_flow[(v - 1) * m + (u - m - 1)] = false;
            } else if u == source {
                src_flow[v - 1] += 1;
            } else if v == source {
                src_flow[u - 1] -= 1;
            } else if v == sink {
                snk_flow[u - m - 1] += 1;
            }
            v = u;
        }
    }

    let mask: Vec<f32> = cell_flow.iter().map(|&f| if f { 1.0 } else { 0.0 }).collect();
    let obj = mask
        .iter()
        .zip(score)
        .map(|(&s, &w)| (s * w) as f64)
        .sum();
    (mask, obj)
}

/// Exact solve over a batch; returns (masks, total objective).
pub fn solve_batch<'a>(scores: impl Into<BlocksView<'a>>, n: usize) -> (Blocks, f64) {
    let scores = scores.into();
    let mut out = Blocks::zeros(scores.b, scores.m);
    let sz = scores.m * scores.m;
    let mut total = 0.0;
    for k in 0..scores.b {
        let (mask, obj) = solve_block(scores.block(k), scores.m, n);
        out.data[k * sz..(k + 1) * sz].copy_from_slice(&mask);
        total += obj;
    }
    (out, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masks::{block_objective, is_transposable_feasible};
    use crate::util::rng::Rng;

    fn random_scores(m: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..m * m).map(|_| rng.heavy_tail().abs()).collect()
    }

    /// Brute force over all transposable masks (tiny M only).
    fn brute_force(score: &[f32], m: usize, n: usize) -> f64 {
        let cells = m * m;
        let mut best = f64::NEG_INFINITY;
        for bits in 0u32..(1 << cells) {
            if bits.count_ones() as usize != n * m {
                continue;
            }
            let mask: Vec<f32> = (0..cells)
                .map(|c| if bits >> c & 1 == 1 { 1.0 } else { 0.0 })
                .collect();
            if is_transposable_feasible(&mask, m, n) {
                best = best.max(block_objective(&mask, score));
            }
        }
        best
    }

    #[test]
    fn matches_brute_force_m4() {
        for seed in 0..15 {
            let s = random_scores(4, seed);
            for n in [1usize, 2, 3] {
                let (mask, obj) = solve_block(&s, 4, n);
                assert!(is_transposable_feasible(&mask, 4, n));
                let bf = brute_force(&s, 4, n);
                assert!(
                    (obj - bf).abs() < 1e-4,
                    "seed={seed} n={n}: flow={obj} bf={bf}"
                );
            }
        }
    }

    #[test]
    fn feasible_all_patterns() {
        for &(m, n) in &[(8usize, 4usize), (8, 2), (16, 8), (16, 4), (32, 16), (32, 8)] {
            let s = random_scores(m, (m * 31 + n) as u64);
            let (mask, _) = solve_block(&s, m, n);
            assert!(is_transposable_feasible(&mask, m, n), "m={m} n={n}");
        }
    }

    #[test]
    fn dominates_heuristics() {
        use crate::masks::rounding;
        for seed in 100..110 {
            let m = 8;
            let n = 4;
            let s = random_scores(m, seed);
            let (_, opt) = solve_block(&s, m, n);
            let heur = rounding::round_block(&s, &s, m, n, 10);
            let hobj = block_objective(&heur, &s);
            assert!(opt >= hobj - 1e-5, "opt {opt} < heuristic {hobj}");
        }
    }

    #[test]
    fn trivial_patterns() {
        let s = random_scores(4, 1);
        let (mask, obj) = solve_block(&s, 4, 0);
        assert_eq!(obj, 0.0);
        assert!(mask.iter().all(|&x| x == 0.0));
        let (mask, obj) = solve_block(&s, 4, 4);
        assert!(mask.iter().all(|&x| x == 1.0));
        assert!((obj - s.iter().map(|&x| x as f64).sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    fn permutation_matrix_for_n1() {
        // n=1: optimal is the max-weight perfect matching (assignment).
        let s = random_scores(8, 42);
        let (mask, _) = solve_block(&s, 8, 1);
        assert!(is_transposable_feasible(&mask, 8, 1));
    }
}
