//! Bi-NM baseline (Zhang et al. 2023, adapted per the paper's App. B.1):
//! magnitude row-wise N:M first (mask S1), then column-wise N:M on the
//! survivors (mask S2); the composite S1 ⊙ S2 satisfies the transposable
//! constraint in the "at most N" sense but routinely leaves rows
//! under-filled — the source of its up-to-50% relative error in Fig. 3.

use crate::util::tensor::{Blocks, BlocksView};

pub fn solve_block(score: &[f32], m: usize, n: usize) -> Vec<f32> {
    // Row-wise top-N.
    let mut mask = vec![0.0f32; m * m];
    let mut idx: Vec<usize> = (0..m).collect();
    for i in 0..m {
        idx.sort_unstable_by(|&a, &b| {
            score[i * m + b]
                .partial_cmp(&score[i * m + a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for &j in idx.iter().take(n) {
            mask[i * m + j] = 1.0;
        }
    }
    // Column-wise top-N among row survivors.
    for j in 0..m {
        let mut rows: Vec<usize> = (0..m).filter(|&i| mask[i * m + j] == 1.0).collect();
        rows.sort_unstable_by(|&a, &b| {
            score[b * m + j]
                .partial_cmp(&score[a * m + j])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for &i in rows.iter().skip(n) {
            mask[i * m + j] = 0.0;
        }
    }
    mask
}

pub fn solve_batch<'a>(scores: impl Into<BlocksView<'a>>, n: usize) -> Blocks {
    let scores = scores.into();
    let mut out = Blocks::zeros(scores.b, scores.m);
    let sz = scores.m * scores.m;
    for k in 0..scores.b {
        let mask = solve_block(scores.block(k), scores.m, n);
        out.data[k * sz..(k + 1) * sz].copy_from_slice(&mask);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn at_most_n_per_row_and_col() {
        let (m, n) = (8usize, 4usize);
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let s: Vec<f32> = (0..m * m).map(|_| rng.heavy_tail().abs()).collect();
            let mask = solve_block(&s, m, n);
            for i in 0..m {
                let r: f32 = mask[i * m..(i + 1) * m].iter().sum();
                assert!(r <= n as f32);
            }
            for j in 0..m {
                let c: f32 = (0..m).map(|i| mask[i * m + j]).sum();
                assert!(c <= n as f32);
            }
        }
    }

    #[test]
    fn typically_underfills() {
        // The weakness the paper exploits: composite mask usually keeps
        // fewer than n*m entries.
        let (m, n) = (16usize, 8usize);
        let mut rng = Rng::new(99);
        let mut total_kept = 0usize;
        let trials = 20;
        for _ in 0..trials {
            let s: Vec<f32> = (0..m * m).map(|_| rng.heavy_tail().abs()).collect();
            let mask = solve_block(&s, m, n);
            total_kept += mask.iter().filter(|&&x| x == 1.0).count();
        }
        assert!(total_kept < trials * n * m, "Bi-NM unexpectedly saturated");
    }
}
