//! Unified solver interface over every mask-generation method, plus the
//! whole-matrix convenience API (partition -> per-block solve -> assemble)
//! and multi-threaded block fan-out.
//!
//! The XLA-accelerated TSENOR path (Dykstra via the AOT HLO artifact) is
//! wired in by the coordinator (`coordinator::batcher`); this module hosts
//! the pure-CPU methods so the algorithm layer stays runtime-free.

use crate::masks::{binm, dykstra, exact, pdlp, random, rounding, two_approx, NmPattern};
use crate::obs;
use crate::util::tensor::{assemble_blocks, partition_blocks, Blocks, BlocksView, Mat};
use anyhow::{bail, Result};

/// Which algorithm generates the transposable masks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Full TSENOR on CPU: entropy-regularized Dykstra + Algorithm-2
    /// rounding (vectorized batch implementation).
    Tsenor,
    /// TSENOR with scalar (block-at-a-time) Dykstra — Table 3's "CPU" row.
    TsenorScalar,
    /// Dykstra + *simple* rounding only — the "Entropy" ablation of Fig. 3.
    EntropySimple,
    /// Greedy on raw weights (2-approximation, Hubara et al.).
    TwoApprox,
    /// Row-then-column N:M composite (Zhang et al.).
    BiNm,
    /// Best of 1000 random feasible masks.
    Max1000,
    /// Restarted PDHG on the LP relaxation (cuPDLP stand-in).
    Pdlp,
    /// Exact min-cost-flow optimum (Network Flow / Gurobi stand-in).
    Exact,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Tsenor => "tsenor",
            Method::TsenorScalar => "tsenor-scalar",
            Method::EntropySimple => "entropy",
            Method::TwoApprox => "2approx",
            Method::BiNm => "binm",
            Method::Max1000 => "max1000",
            Method::Pdlp => "pdlp",
            Method::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Method> {
        Method::all()
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown method '{s}' (valid: {})",
                    Method::all().iter().map(|m| m.name()).collect::<Vec<_>>().join("|")
                )
            })
    }

    pub fn all() -> &'static [Method] {
        &[
            Method::Tsenor,
            Method::TsenorScalar,
            Method::EntropySimple,
            Method::TwoApprox,
            Method::BiNm,
            Method::Max1000,
            Method::Pdlp,
            Method::Exact,
        ]
    }
}

/// Tuning knobs shared across methods.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolveCfg {
    pub dykstra: dykstra::DykstraCfg,
    pub ls_steps: usize,
    pub random_k: usize,
    pub seed: u64,
    pub threads: usize,
    /// Internal: fixed tau (set by the parallel driver so chunked solves
    /// normalize by the GLOBAL max |W|, matching the serial path bit-wise).
    pub tau_override: Option<f32>,
    /// Internal: global index of the first block in this (sub-)batch.
    pub block_offset: usize,
}

impl Default for SolveCfg {
    fn default() -> Self {
        SolveCfg {
            dykstra: dykstra::DykstraCfg::default(),
            ls_steps: 10,
            random_k: 1000,
            seed: 0,
            threads: 1,
            tau_override: None,
            block_offset: 0,
        }
    }
}

fn batch_tau(scores: BlocksView<'_>, cfg: &SolveCfg) -> f32 {
    cfg.tau_override.unwrap_or_else(|| {
        let max_abs = scores.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        dykstra::effective_tau(max_abs, cfg.dykstra.tau0)
    })
}

/// Reject non-finite scores before any solve touches them. `f32::max`
/// silently drops NaN (`NaN.max(x) == x`), so a NaN score used to sail
/// through `batch_tau`'s max-|W| fold and produce a garbage mask with no
/// diagnostic; every public entry point now fails loudly instead,
/// naming the offending block. Crate-visible so the XLA path
/// (`coordinator::batcher`) gates its tau fold with the same check.
pub(crate) fn validate_scores(scores: BlocksView<'_>) -> Result<()> {
    let sz = scores.m * scores.m;
    for (at, &x) in scores.data.iter().enumerate() {
        if !x.is_finite() {
            bail!(
                "solver: non-finite score {x} in block {} (offset {} within the block); \
                 masks solved from NaN/inf scores would be garbage",
                at / sz.max(1),
                at % sz.max(1),
            );
        }
    }
    Ok(())
}

/// TSENOR on CPU: Algorithm 1 (batch) + Algorithm 2. Private on
/// purpose: it skips `validate_scores` (its callers pre-screen), so
/// exposing it would reopen the silent-NaN hole the public entry
/// points close.
fn tsenor_cpu(
    scores: BlocksView<'_>,
    n: usize,
    cfg: &SolveCfg,
    parent: obs::SpanId,
) -> Blocks {
    // Phase spans sample the chunk holding global block 0 only
    // (`block_offset == 0`), so the span tree is identical at every
    // `threads` level: exactly one dykstra + one round span per batch
    // solve, parented on the batch span whichever thread runs them.
    let probe = cfg.block_offset == 0;
    let tau = batch_tau(scores, cfg);
    let frac = {
        let _s = probe
            .then(|| obs::span_at("solve.dykstra", parent).kv("blocks", scores.b));
        dykstra::solve_batch(scores, n, tau, cfg.dykstra.iters)
    };
    let _s = probe.then(|| obs::span_at("solve.round", parent).kv("blocks", scores.b));
    rounding::round_batch(&frac, scores, n, cfg.ls_steps)
}

fn tsenor_scalar(scores: BlocksView<'_>, n: usize, cfg: &SolveCfg) -> Blocks {
    let tau = batch_tau(scores, cfg);
    let mut out = Blocks::zeros(scores.b, scores.m);
    let sz = scores.m * scores.m;
    for k in 0..scores.b {
        let frac =
            dykstra::solve_block_scalar(scores.block(k), scores.m, n, tau, cfg.dykstra.iters);
        let mask = rounding::round_block(&frac, scores.block(k), scores.m, n, cfg.ls_steps);
        out.data[k * sz..(k + 1) * sz].copy_from_slice(&mask);
    }
    out
}

fn entropy_simple(scores: BlocksView<'_>, n: usize, cfg: &SolveCfg) -> Blocks {
    let tau = batch_tau(scores, cfg);
    let frac = dykstra::solve_batch(scores, n, tau, cfg.dykstra.iters);
    let mut out = Blocks::zeros(scores.b, scores.m);
    let sz = scores.m * scores.m;
    for k in 0..scores.b {
        let mask = rounding::simple_round(frac.block(k), scores.m, n);
        out.data[k * sz..(k + 1) * sz].copy_from_slice(&mask);
    }
    out
}

/// Method dispatch over a (pre-validated) borrowed batch. Infallible:
/// every failure mode is screened by `validate_scores` at the public
/// entry points, so per-chunk workers need no error plumbing.
fn dispatch(
    method: Method,
    scores: BlocksView<'_>,
    n: usize,
    cfg: &SolveCfg,
    parent: obs::SpanId,
) -> Blocks {
    match method {
        Method::Tsenor => tsenor_cpu(scores, n, cfg, parent),
        Method::TsenorScalar => tsenor_scalar(scores, n, cfg),
        Method::EntropySimple => entropy_simple(scores, n, cfg),
        Method::TwoApprox => two_approx::solve_batch(scores, n),
        Method::BiNm => binm::solve_batch(scores, n),
        Method::Max1000 => {
            random::solve_batch_offset(scores, n, cfg.random_k, cfg.seed, cfg.block_offset)
        }
        Method::Pdlp => pdlp::solve_batch(scores, n, pdlp::PdlpCfg::default()),
        Method::Exact => exact::solve_batch(scores, n).0,
    }
}

/// Solve a batch of blocks with the chosen method (single thread).
/// Errors on non-finite scores, naming the block.
pub fn solve_blocks(method: Method, scores: &Blocks, n: usize, cfg: &SolveCfg) -> Result<Blocks> {
    let span = obs::span("solve.batch")
        .kv("method", method.name())
        .kv("b", scores.b)
        .kv("m", scores.m)
        .kv("n", n);
    validate_scores(scores.view())?;
    Ok(dispatch(method, scores.view(), n, cfg, span.id()))
}

/// Solve a batch with `cfg.threads`-way fan-out over block chunks.
///
/// §Memory: workers solve *borrowed* sub-ranges of `scores`
/// ([`Blocks::range`]) — the fan-out owns only the output batch. The
/// chunks were `.to_vec()` copies once, which transiently doubled the
/// layer's score footprint at exactly the moment a `--memory-budget`
/// run is tightest (the copies sat outside `stream_peak_bytes`
/// accounting); `tests/solver_memory.rs` pins the no-copy behavior.
pub fn solve_blocks_parallel(
    method: Method,
    scores: &Blocks,
    n: usize,
    cfg: &SolveCfg,
) -> Result<Blocks> {
    let threads = cfg.threads.max(1);
    if threads == 1 || scores.b < 2 * threads {
        return solve_blocks(method, scores, n, cfg);
    }
    let span = obs::span("solve.batch")
        .kv("method", method.name())
        .kv("b", scores.b)
        .kv("m", scores.m)
        .kv("n", n);
    let parent = span.id();
    validate_scores(scores.view())?;
    // Normalize tau by the GLOBAL max so chunking is invisible.
    let mut cfg = *cfg;
    cfg.tau_override = Some(batch_tau(scores.view(), &cfg));
    let cfg = &cfg;
    let sz = scores.m * scores.m;
    let chunk = scores.b.div_ceil(threads);
    let mut out = Blocks::zeros(scores.b, scores.m);
    let slices: Vec<(usize, &mut [f32])> = {
        let mut res = Vec::new();
        let mut rest: &mut [f32] = &mut out.data;
        let mut start = 0usize;
        while start < scores.b {
            let take = chunk.min(scores.b - start);
            let (head, tail) = rest.split_at_mut(take * sz);
            res.push((start, head));
            rest = tail;
            start += take;
        }
        res
    };
    // Block-chunk fan-out over pre-split disjoint slices; predates
    // and mirrors sparse::fan_out_rows.
    crate::sync::thread::scope(|scope| {
        for (start, dst) in slices {
            let nblocks = dst.len() / sz;
            let sub = scores.range(start, nblocks);
            let mut cfg = *cfg;
            cfg.block_offset += start;
            scope.spawn(move || {
                let solved = dispatch(method, sub, n, &cfg, parent);
                dst.copy_from_slice(&solved.data);
            });
        }
    });
    Ok(out)
}

/// Whole-matrix API: transposable N:M mask of `w` maximizing kept |W|
/// (or any externally-supplied score matrix of identical shape).
/// Errors on non-finite scores, naming the block.
pub fn solve_matrix(
    method: Method,
    score: &Mat,
    pattern: NmPattern,
    cfg: &SolveCfg,
) -> Result<Mat> {
    let blocks = partition_blocks(&score.abs(), pattern.m);
    let masks = solve_blocks_parallel(method, &blocks, pattern.n, cfg)?;
    Ok(assemble_blocks(&masks, score.rows, score.cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::masks::{batch_feasible, batch_objective};
    use crate::util::rng::Rng;

    fn random_blocks(b: usize, m: usize, seed: u64) -> Blocks {
        let mut rng = Rng::new(seed);
        let data = (0..b * m * m).map(|_| rng.heavy_tail().abs()).collect();
        Blocks { b, m, data }
    }

    #[test]
    fn all_methods_feasible_except_binm() {
        let scores = random_blocks(4, 8, 21);
        let cfg = SolveCfg { random_k: 50, ..Default::default() };
        for &method in Method::all() {
            let masks = solve_blocks(method, &scores, 4, &cfg).unwrap();
            if method == Method::BiNm || method == Method::EntropySimple {
                continue; // allowed to underfill by construction
            }
            assert!(batch_feasible(&masks, 4), "{} infeasible", method.name());
        }
    }

    #[test]
    fn quality_ordering_holds() {
        // exact >= tsenor >= 2approx-ish >= max1000 on average.
        let scores = random_blocks(16, 8, 33);
        let cfg = SolveCfg { random_k: 200, ..Default::default() };
        let f = |m: Method| {
            let masks = solve_blocks(m, &scores, 4, &cfg).unwrap();
            batch_objective(&masks, &scores)
        };
        let exact = f(Method::Exact);
        let tsenor = f(Method::Tsenor);
        let approx = f(Method::TwoApprox);
        let rand = f(Method::Max1000);
        assert!(exact >= tsenor - 1e-6);
        assert!(tsenor >= approx - 1e-6, "tsenor {tsenor} < 2approx {approx}");
        assert!(tsenor > rand, "tsenor {tsenor} <= max1000 {rand}");
    }

    #[test]
    fn parallel_matches_serial_all_methods() {
        // Chunked fan-out must be invisible for EVERY method: tau is
        // normalized by the global max, and the randomized method seeds
        // per global block index.
        let scores = random_blocks(13, 8, 44);
        let cfg1 = SolveCfg { random_k: 60, ..Default::default() };
        let cfg4 = SolveCfg { threads: 4, random_k: 60, ..Default::default() };
        for &method in Method::all() {
            let a = solve_blocks(method, &scores, 4, &cfg1).unwrap();
            let b = solve_blocks_parallel(method, &scores, 4, &cfg4).unwrap();
            assert_eq!(a.data, b.data, "{}: parallel != serial", method.name());
        }
    }

    #[test]
    fn non_finite_scores_rejected_naming_the_block() {
        // A planted NaN must fail loudly at every entry point — not
        // silently vanish inside `f32::max` and yield a garbage mask.
        let mut scores = random_blocks(5, 8, 61);
        scores.data[2 * 64 + 13] = f32::NAN;
        let cfg = SolveCfg::default();
        let err = solve_blocks(Method::Tsenor, &scores, 4, &cfg).unwrap_err().to_string();
        assert!(err.contains("block 2"), "{err}");
        assert!(err.contains("NaN"), "{err}");
        let cfg4 = SolveCfg { threads: 4, ..Default::default() };
        assert!(solve_blocks_parallel(Method::Tsenor, &scores, 4, &cfg4).is_err());
        // Infinities are just as poisonous to tau normalization.
        scores.data[2 * 64 + 13] = f32::INFINITY;
        let err = solve_blocks(Method::Tsenor, &scores, 4, &cfg).unwrap_err().to_string();
        assert!(err.contains("inf") && err.contains("block 2"), "{err}");
        // And the whole-matrix API reports through the same check.
        let mut w = Mat::from_fn(16, 16, |i, j| (1 + i + j) as f32);
        *w.at_mut(9, 1) = f32::NAN; // second 8x8 block row -> block 2
        let err = solve_matrix(Method::Tsenor, &w, NmPattern::new(4, 8), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("block 2"), "{err}");
    }

    #[test]
    fn m64_patterns_take_the_vectorized_path_end_to_end() {
        // The compression-accuracy frontier patterns (16:64, 32:64) must
        // run the full vectorized TSENOR stack: feasible masks, near
        // scalar-path quality, and chunked fan-out still bit-invisible.
        let scores = random_blocks(6, 64, 91);
        let cfg = SolveCfg::default();
        for n in [16usize, 32] {
            let masks = solve_blocks(Method::Tsenor, &scores, n, &cfg).unwrap();
            assert!(batch_feasible(&masks, n), "16:64-class mask infeasible at n={n}");
            let scalar = solve_blocks(Method::TsenorScalar, &scores, n, &cfg).unwrap();
            let ov = batch_objective(&masks, &scores);
            let os = batch_objective(&scalar, &scores);
            assert!((ov - os).abs() / ov.abs() < 1e-3, "n={n}: {ov} vs {os}");
            let cfg3 = SolveCfg { threads: 3, ..Default::default() };
            let par = solve_blocks_parallel(Method::Tsenor, &scores, n, &cfg3).unwrap();
            assert_eq!(masks.data, par.data, "n={n}: parallel != serial at M=64");
        }
    }

    #[test]
    fn method_parse_roundtrip_and_errors() {
        for &m in Method::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        let err = Method::parse("simplex").unwrap_err().to_string();
        assert!(err.contains("tsenor") && err.contains("pdlp"), "{err}");
    }

    #[test]
    fn matrix_api_shapes() {
        let mut rng = Rng::new(9);
        let w = Mat::from_fn(16, 32, |_, _| rng.heavy_tail());
        let mask = solve_matrix(
            Method::Tsenor,
            &w,
            NmPattern::new(4, 8),
            &SolveCfg::default(),
        )
        .unwrap();
        assert_eq!((mask.rows, mask.cols), (16, 32));
        // Transposable: row & col sums inside each 8x8 block are 4.
        let blocks = partition_blocks(&mask, 8);
        assert!(batch_feasible(&blocks, 4));
    }

    #[test]
    fn scalar_matches_vectorized_tsenor() {
        let scores = random_blocks(6, 8, 55);
        let cfg = SolveCfg::default();
        let a = solve_blocks(Method::Tsenor, &scores, 4, &cfg).unwrap();
        let b = solve_blocks(Method::TsenorScalar, &scores, 4, &cfg).unwrap();
        // Same algorithm, same order of float ops in rounding; dykstra
        // differs only in reduction order -> identical masks expected on
        // well-separated inputs. Compare objectives with tolerance.
        let oa = batch_objective(&a, &scores);
        let ob = batch_objective(&b, &scores);
        assert!((oa - ob).abs() / oa.abs() < 1e-3, "{oa} vs {ob}");
    }
}
