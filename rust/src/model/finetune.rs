//! Masked fine-tuning (Fig. 5): the Rust coordinator owns the optimizer
//! state and drives the AOT model_grad artifact; gradients flow through
//! the L1 masked-GEMM kernel whose VJP realizes the transposable-sparsity
//! backward pass. Python is not involved.

// Everything below `FinetuneCfg` drives the AOT model_grad artifact,
// so the optimizer loop itself is XLA-gated; the config stays
// available to `spec` in every build.
#[cfg(feature = "backend-xla")]
use crate::data::loader::random_batch;
#[cfg(feature = "backend-xla")]
use crate::model::ModelState;
#[cfg(feature = "backend-xla")]
use crate::runtime::client::ModelRuntime;
#[cfg(feature = "backend-xla")]
use crate::util::rng::Rng;
#[cfg(feature = "backend-xla")]
use crate::util::tensor::Mat;
#[cfg(feature = "backend-xla")]
use anyhow::Result;
#[cfg(feature = "backend-xla")]
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug)]
pub struct FinetuneCfg {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub seed: u64,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg {
            steps: 50,
            lr: 2e-4,
            warmup: 5,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            seed: 1234,
        }
    }
}

/// Adam state per weight tensor.
#[cfg(feature = "backend-xla")]
struct Adam {
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
    t: usize,
}

#[cfg(feature = "backend-xla")]
impl Adam {
    fn new(weights: &BTreeMap<String, Mat>) -> Self {
        let m = weights
            .iter()
            .map(|(k, w)| (k.clone(), vec![0.0; w.data.len()]))
            .collect();
        let v = weights
            .iter()
            .map(|(k, w)| (k.clone(), vec![0.0; w.data.len()]))
            .collect();
        Adam { m, v, t: 0 }
    }

    fn step(&mut self, cfg: &FinetuneCfg, lr: f32, name: &str, w: &mut Mat, g: &Mat) {
        let m = self.m.get_mut(name).unwrap();
        let v = self.v.get_mut(name).unwrap();
        let t = self.t as f32;
        let bc1 = 1.0 - cfg.beta1.powf(t);
        let bc2 = 1.0 - cfg.beta2.powf(t);
        for ((wv, gv), (mv, vv)) in w
            .data
            .iter_mut()
            .zip(&g.data)
            .zip(m.iter_mut().zip(v.iter_mut()))
        {
            *mv = cfg.beta1 * *mv + (1.0 - cfg.beta1) * gv;
            *vv = cfg.beta2 * *vv + (1.0 - cfg.beta2) * gv * gv;
            let mhat = *mv / bc1;
            let vhat = *vv / bc2;
            *wv -= lr * mhat / (vhat.sqrt() + cfg.eps);
        }
    }
}

/// Run masked fine-tuning; returns the per-step loss curve.
#[cfg(feature = "backend-xla")]
pub fn finetune(
    rt: &ModelRuntime,
    state: &mut ModelState,
    train: &[u8],
    cfg: &FinetuneCfg,
) -> Result<Vec<f32>> {
    let art = &rt.manifest.model_grad;
    let mut adam = Adam::new(&state.weights);
    let mut rng = Rng::new(cfg.seed);
    let mut curve = Vec::with_capacity(cfg.steps);

    // Masks must exist for every prunable tensor (default: all-ones).
    for info in rt.manifest.weights.iter().filter(|w| w.prunable) {
        state.masks.entry(info.name.clone()).or_insert_with(|| {
            Mat::from_fn(info.shape[0], info.shape[1], |_, _| 1.0)
        });
    }

    for step in 1..=cfg.steps {
        adam.t = step;
        let tokens = random_batch(train, art.batch, art.seq, &mut rng);
        let (loss, grads) = rt.grads(&state.weights, &state.masks, &tokens)?;
        let lr = cfg.lr * (step as f32 / cfg.warmup.max(1) as f32).min(1.0);
        for (info, g) in rt.manifest.weights.iter().zip(&grads) {
            let w = state.weights.get_mut(&info.name).unwrap();
            adam.step(cfg, lr, &info.name, w, g);
        }
        // Keep pruned coordinates exactly zero.
        state.reproject();
        curve.push(loss);
    }
    Ok(curve)
}
