//! Model state at runtime: named weight tensors (loaded from the artifact
//! bundle), per-layer masks, and the masked fine-tuning loop (Fig. 5).

pub mod finetune;

use crate::util::tensor::Mat;
use std::collections::BTreeMap;

/// Mutable model state: weights + optional masks over prunable tensors.
#[derive(Clone, Debug, Default)]
pub struct ModelState {
    pub weights: BTreeMap<String, Mat>,
    pub masks: BTreeMap<String, Mat>,
}

impl ModelState {
    pub fn new(weights: BTreeMap<String, Mat>) -> Self {
        ModelState { weights, masks: BTreeMap::new() }
    }

    /// Install a mask and zero the pruned weights.
    pub fn apply_mask(&mut self, name: &str, mask: Mat) {
        if let Some(w) = self.weights.get_mut(name) {
            assert_eq!((w.rows, w.cols), (mask.rows, mask.cols), "{name} mask shape");
            *w = w.hadamard(&mask);
        }
        self.masks.insert(name.to_string(), mask);
    }

    /// Replace a weight tensor (e.g. with the SparseGPT/ALPS update) and
    /// record its mask.
    pub fn set_pruned(&mut self, name: &str, w: Mat, mask: Mat) {
        self.weights.insert(name.to_string(), w);
        self.masks.insert(name.to_string(), mask);
    }

    /// Fraction of zeros among prunable (masked) weights.
    pub fn sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for (name, mask) in &self.masks {
            let _ = name;
            zeros += mask.data.iter().filter(|&&x| x == 0.0).count();
            total += mask.data.len();
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }

    /// Re-project weights onto their masks (after a fine-tune step the
    /// optimizer may drift off-support only through numerical error, but
    /// we enforce exactness).
    pub fn reproject(&mut self) {
        for (name, mask) in &self.masks {
            if let Some(w) = self.weights.get_mut(name) {
                for (wv, mv) in w.data.iter_mut().zip(&mask.data) {
                    *wv *= mv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn state() -> ModelState {
        let mut rng = Rng::new(1);
        let mut weights = BTreeMap::new();
        weights.insert("a".into(), Mat::from_fn(4, 4, |_, _| rng.normal()));
        weights.insert("b".into(), Mat::from_fn(4, 4, |_, _| rng.normal()));
        ModelState::new(weights)
    }

    #[test]
    fn apply_mask_zeroes() {
        let mut st = state();
        let mut mask = Mat::zeros(4, 4);
        for i in 0..8 {
            mask.data[i] = 1.0;
        }
        st.apply_mask("a", mask);
        assert_eq!(st.sparsity(), 0.5);
        assert!(st.weights["a"].data[8..].iter().all(|&x| x == 0.0));
        assert!(st.weights["a"].data[..8].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn reproject_restores_support() {
        let mut st = state();
        let mut mask = Mat::zeros(4, 4);
        mask.data[0] = 1.0;
        st.apply_mask("a", mask);
        st.weights.get_mut("a").unwrap().data[5] = 3.0; // drift off-support
        st.reproject();
        assert_eq!(st.weights["a"].data[5], 0.0);
        assert_ne!(st.weights["a"].data[0], 0.0);
    }
}
