"""Build-time data: corpus generators and probe construction."""

import json

import numpy as np
import pytest

from compile import corpus as C


def test_corpora_shapes_and_determinism():
    a = C.build_corpora(7, 1 << 14, 1 << 12)
    b = C.build_corpora(7, 1 << 14, 1 << 12)
    assert set(a) == {"train", "valid_markov", "valid_zipf", "valid_template"}
    for k in a:
        assert a[k].dtype == np.uint8
        np.testing.assert_array_equal(a[k], b[k])
    for k in ("valid_markov", "valid_zipf", "valid_template"):
        assert len(a[k]) == 1 << 12


def test_corpora_distributions_differ():
    c = C.build_corpora(3, 1 << 14, 1 << 12)

    def hist(x):
        h = np.bincount(x, minlength=256).astype(np.float64)
        return h / h.sum()

    hm, hz, ht = (hist(c[k]) for k in ("valid_markov", "valid_zipf", "valid_template"))
    # L1 distances between corpus byte distributions must be substantial.
    assert np.abs(hm - ht).sum() > 0.3
    assert np.abs(hz - ht).sum() > 0.3


def test_template_contains_queries():
    t = C.gen_template(np.random.default_rng(0), 4096).tobytes()
    assert b"?" in t and b"=" in t and b";" in t


@pytest.fixture(scope="module")
def probes():
    return C.build_probes(11, n_items=20)


def test_probes_all_tasks_present(probes):
    assert set(probes) == {
        "bigram", "word_completion", "retrieval", "copy",
        "majority", "repetition", "delimiter", "query_marker",
    }
    for task, items in probes.items():
        assert len(items) == 20, task
        for it in items:
            assert 0 <= it["answer"] < len(it["choices"]), task
            assert len(it["context"]) >= 1
            assert all(len(c) >= 1 for c in it["choices"])
            # items must fit the model_fwd window (128) incl. choice
            assert len(it["context"]) + max(len(c) for c in it["choices"]) <= 128


def test_retrieval_answer_is_recoverable(probes):
    # the correct value must literally appear in the context records
    for it in probes["retrieval"]:
        ctx = bytes(it["context"])
        assert bytes(it["choices"][it["answer"]]) in ctx


def test_probes_json_roundtrip(probes):
    text = C.probes_to_json(probes)
    back = json.loads(text)
    assert set(back) == set(probes)
    item = back["copy"][0]
    assert isinstance(item["context"], list)
    assert all(isinstance(x, int) and 0 <= x < 256 for x in item["context"])
