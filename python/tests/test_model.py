"""L2 correctness: transformer shapes, loss semantics, masked fine-tune
gradients, calibration Gram identities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def small_cfg():
    return M.Config(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


@pytest.fixture(scope="module")
def weights(small_cfg):
    return M.init_weights(jax.random.PRNGKey(0), small_cfg)


def toks(cfg, batch, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.seq_len), dtype=np.int32))


def test_weight_names_shapes_consistent(small_cfg):
    names = M.weight_names(small_cfg)
    shapes = M.weight_shapes(small_cfg)
    assert len(names) == len(set(names))
    assert set(names) == set(shapes)
    assert names[0] == "embed" and names[-1] == "lnf"
    # prunable = 7 linears per layer
    assert len(M.prunable_names(small_cfg)) == 7 * small_cfg.n_layers


def test_forward_shapes_and_loss(small_cfg, weights):
    t = toks(small_cfg, 3)
    logits = M.forward_logits(small_cfg, weights, t)
    assert logits.shape == (3, small_cfg.seq_len, small_cfg.vocab)
    loss, logp = M.loss_and_logprobs(small_cfg, weights, t)
    assert logp.shape == (3, small_cfg.seq_len - 1)
    # random init => loss near ln(vocab)
    assert abs(float(loss) - np.log(small_cfg.vocab)) < 0.5
    # loss equals mean(-logp)
    np.testing.assert_allclose(float(loss), -float(jnp.mean(logp)), rtol=1e-5)


def test_causality(small_cfg, weights):
    """Changing a future token must not change past logprobs."""
    t1 = toks(small_cfg, 1, seed=1)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % small_cfg.vocab)
    _, lp1 = M.loss_and_logprobs(small_cfg, weights, t1)
    _, lp2 = M.loss_and_logprobs(small_cfg, weights, t2)
    # all positions except the last are unaffected
    np.testing.assert_allclose(np.asarray(lp1)[0, :-1], np.asarray(lp2)[0, :-1], atol=1e-5)


def test_finetune_grads_respect_masks(small_cfg, weights):
    rng = np.random.default_rng(3)
    shapes = M.weight_shapes(small_cfg)
    masks = [
        jnp.asarray((rng.random(shapes[n]) < 0.5).astype(np.float32))
        for n in M.prunable_names(small_cfg)
    ]
    t = toks(small_cfg, 2, seed=2)
    loss, *grads = M.finetune_loss_and_grads(small_cfg, weights, masks, t)
    assert np.isfinite(float(loss))
    names = M.weight_names(small_cfg)
    prunable = set(M.prunable_names(small_cfg))
    mask_by_name = dict(zip(M.prunable_names(small_cfg), masks))
    for name, g in zip(names, grads):
        assert g.shape == shapes[name], name
        if name in prunable:
            leaked = np.asarray(g)[np.asarray(mask_by_name[name]) == 0.0]
            assert np.all(leaked == 0.0), f"gradient leak in {name}"


def test_masked_forward_equals_masked_weights(small_cfg, weights):
    """finetune forward with mask == plain forward on pre-masked weights."""
    rng = np.random.default_rng(4)
    shapes = M.weight_shapes(small_cfg)
    prunable = M.prunable_names(small_cfg)
    masks = [
        jnp.asarray((rng.random(shapes[n]) < 0.5).astype(np.float32)) for n in prunable
    ]
    t = toks(small_cfg, 2, seed=5)
    loss_masked = M.finetune_loss(small_cfg, weights, masks, t)
    names = M.weight_names(small_cfg)
    mask_by_name = dict(zip(prunable, masks))
    weights2 = [
        w * mask_by_name[n] if n in mask_by_name else w for n, w in zip(names, weights)
    ]
    loss_direct, _ = M.loss_and_logprobs(small_cfg, weights2, t)
    np.testing.assert_allclose(float(loss_masked), float(loss_direct), rtol=1e-4)


def test_calibration_gram_identity(small_cfg, weights):
    """Gram outputs must equal X^T X of the captured activations."""
    t = toks(small_cfg, 2, seed=6)
    loss, *grams = M.calibration_grams(small_cfg, weights, t)
    assert np.isfinite(float(loss))
    sites = M.gram_sites(small_cfg)
    assert len(grams) == len(sites) == 4 * small_cfg.n_layers
    for site, g in zip(sites, grams):
        g = np.asarray(g)
        assert g.shape == (site["dim"], site["dim"])
        np.testing.assert_allclose(g, g.T, atol=1e-2)
        evals = np.linalg.eigvalsh(g.astype(np.float64))
        assert evals.min() > -1e-3, site["name"]


def test_gram_sites_cover_all_prunables(small_cfg):
    covered = {w for s in M.gram_sites(small_cfg) for w in s["weights"]}
    assert covered == set(M.prunable_names(small_cfg))
