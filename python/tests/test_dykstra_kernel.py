"""L1 correctness: the Pallas Dykstra kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes, patterns and regularization strengths; the kernel must
track the oracle bit-for-bit-ish (same op order => tight tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dykstra import dykstra_pallas
from compile.kernels.ref import dykstra_ref

TOL = 1e-5


def run_both(absw, n, tau, iters):
    logn = float(np.log(n))
    got = np.asarray(dykstra_pallas(jnp.asarray(absw), tau, logn, iters=iters))
    want = np.asarray(dykstra_ref(jnp.asarray(absw), tau, logn, iters=iters))
    return got, want


@pytest.mark.parametrize("m,n", [(4, 2), (8, 4), (8, 2), (16, 8), (32, 16)])
def test_matches_ref_basic(m, n):
    rng = np.random.default_rng(m * 31 + n)
    absw = np.abs(rng.standard_normal((6, m, m))).astype(np.float32)
    tau = 120.0 / float(absw.max())
    got, want = run_both(absw, n, tau, 100)
    np.testing.assert_allclose(got, want, atol=TOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    m=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
    tau0=st.floats(1.0, 300.0),
    iters=st.integers(1, 120),
)
def test_matches_ref_hypothesis(b, m, seed, tau0, iters):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, m + 1))
    absw = np.abs(rng.standard_normal((b, m, m))).astype(np.float32)
    tau = tau0 / max(float(absw.max()), 1e-6)
    got, want = run_both(absw, n, tau, iters)
    np.testing.assert_allclose(got, want, atol=TOL)


def test_marginals_converge_to_n():
    rng = np.random.default_rng(0)
    m, n = 16, 8
    absw = np.abs(rng.standard_normal((4, m, m))).astype(np.float32)
    tau = 120.0 / float(absw.max())
    got = np.asarray(dykstra_pallas(jnp.asarray(absw), tau, float(np.log(n)), iters=300))
    np.testing.assert_allclose(got.sum(axis=2), n, atol=0.2)
    np.testing.assert_allclose(got.sum(axis=1), n, atol=0.2)
    assert got.min() >= 0.0
    assert got.max() <= 1.0 + 1e-5


def test_entries_bounded_even_with_extreme_tau():
    rng = np.random.default_rng(1)
    absw = np.abs(rng.standard_normal((2, 8, 8))).astype(np.float32)
    got = np.asarray(dykstra_pallas(jnp.asarray(absw), 500.0, float(np.log(4)), iters=50))
    assert np.isfinite(got).all()
    assert got.max() <= 1.0 + 1e-4


def test_uneven_batch_tiles():
    # batch not a multiple of the preferred tile => _tile_batch fallback.
    rng = np.random.default_rng(2)
    absw = np.abs(rng.standard_normal((7, 8, 8))).astype(np.float32)
    tau = 60.0 / float(absw.max())
    got, want = run_both(absw, 4, tau, 60)
    np.testing.assert_allclose(got, want, atol=TOL)


def test_n_equals_m_saturates():
    rng = np.random.default_rng(3)
    m = 8
    absw = np.abs(rng.standard_normal((3, m, m))).astype(np.float32)
    got = np.asarray(dykstra_pallas(jnp.asarray(absw), 10.0, float(np.log(m)), iters=200))
    np.testing.assert_allclose(got, 1.0, atol=1e-3)
