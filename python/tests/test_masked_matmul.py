"""L1 correctness: masked GEMM Pallas kernel + its custom VJP vs jnp."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_matmul import masked_matmul
from compile.kernels.ref import masked_matmul_ref


def rand_case(rng, n, k, m, density=0.5):
    x = rng.standard_normal((n, k)).astype(np.float32)
    w = rng.standard_normal((k, m)).astype(np.float32)
    mask = (rng.random((k, m)) < density).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)


def test_forward_matches_ref():
    rng = np.random.default_rng(0)
    x, w, mask = rand_case(rng, 64, 32, 48)
    got = masked_matmul(x, w, mask)
    want = masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 96),
    k=st.integers(1, 64),
    m=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_forward_hypothesis(n, k, m, seed):
    rng = np.random.default_rng(seed)
    x, w, mask = rand_case(rng, n, k, m, density=float(rng.random()))
    got = masked_matmul(x, w, mask)
    want = masked_matmul_ref(x, w, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_gradients_match_ref_and_respect_mask():
    rng = np.random.default_rng(1)
    x, w, mask = rand_case(rng, 32, 16, 24)

    def loss_pallas(w_, x_):
        return (masked_matmul(x_, w_, mask) ** 2).sum()

    def loss_ref(w_, x_):
        return (masked_matmul_ref(x_, w_, mask) ** 2).sum()

    gw_p, gx_p = jax.grad(loss_pallas, argnums=(0, 1))(w, x)
    gw_r, gx_r = jax.grad(loss_ref, argnums=(0, 1))(w, x)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=1e-3, atol=1e-3)
    # No gradient leaks to pruned weights.
    assert np.all(np.asarray(gw_p)[np.asarray(mask) == 0.0] == 0.0)


def test_mask_gradient_is_none_passthrough():
    # VJP declares no mask gradient; differentiating w.r.t. x and w only.
    rng = np.random.default_rng(2)
    x, w, mask = rand_case(rng, 8, 8, 8)
    y, vjp = jax.vjp(lambda x_, w_: masked_matmul(x_, w_, mask), x, w)
    dx, dw = vjp(jnp.ones_like(y))
    assert dx.shape == x.shape
    assert dw.shape == w.shape
