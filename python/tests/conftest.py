import os
import sys

# Tests run from python/ (see Makefile); make `compile` importable either way.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
