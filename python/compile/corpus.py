"""Synthetic corpora + zero-shot probe construction (build-time only).

Stand-ins for WikiText2 / PTB / C4 and the LM-harness tasks (DESIGN.md
§Substitutions). Three validation distributions with distinct statistics,
a mixed training stream, and eight multiple-choice probe tasks whose
ground truth comes from the generators themselves.

Tokenization is byte-level (vocab 256); every stream is a u8 array.
"""

from __future__ import annotations

import json

import numpy as np

# Alphabet for markov text: lowercase letters + space.
_MARKOV_SYMS = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz ", dtype=np.uint8)


def _markov_table(rng: np.random.Generator, k: int) -> np.ndarray:
    """Sparse-ish row-stochastic transition table over k symbols."""
    t = rng.dirichlet(np.full(k, 0.08), size=k)
    return t.astype(np.float64)


def gen_markov(rng: np.random.Generator, length: int) -> np.ndarray:
    """Order-1 Markov chain over letters+space (the 'WikiText2' stand-in)."""
    k = len(_MARKOV_SYMS)
    table = _markov_table(rng, k)
    cdf = np.cumsum(table, axis=1)
    out = np.empty(length, dtype=np.int64)
    state = int(rng.integers(k))
    u = rng.random(length)
    for i in range(length):
        state = int(np.searchsorted(cdf[state], u[i]))
        if state >= k:
            state = k - 1
        out[i] = state
    return _MARKOV_SYMS[out]


def _lexicon(rng: np.random.Generator, size: int) -> list[bytes]:
    words = set()
    while len(words) < size:
        n = int(rng.integers(2, 8))
        w = bytes(rng.choice(_MARKOV_SYMS[:26], size=n))
        words.add(w)
    return sorted(words)


def gen_zipf(rng: np.random.Generator, length: int, lex_size: int = 500) -> np.ndarray:
    """Zipf-distributed word stream (the 'PTB' stand-in)."""
    lex = _lexicon(rng, lex_size)
    ranks = np.arange(1, lex_size + 1, dtype=np.float64)
    probs = (1.0 / ranks ** 1.1)
    probs /= probs.sum()
    chunks: list[bytes] = []
    total = 0
    while total < length:
        idx = rng.choice(lex_size, size=256, p=probs)
        for w in idx:
            chunks.append(lex[int(w)])
            total += len(lex[int(w)]) + 1
    return np.frombuffer(b" ".join(chunks)[:length], dtype=np.uint8).copy()


_KEY_ALPHA = np.frombuffer(b"ABCDEFGHIJKLMNOPQRSTUVWXYZ", dtype=np.uint8)
_VAL_ALPHA = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz0123456789", dtype=np.uint8)


def _record(rng: np.random.Generator) -> tuple[bytes, bytes, bytes]:
    key = bytes(rng.choice(_KEY_ALPHA, size=2)) + bytes([int(rng.integers(48, 58))])
    val = bytes(rng.choice(_VAL_ALPHA, size=4))
    return key, val, key + b":" + val + b";"


def gen_template(rng: np.random.Generator, length: int) -> np.ndarray:
    """Structured key-value records with retrieval queries (the 'C4'
    stand-in, and the source of copy/retrieval capability)."""
    parts: list[bytes] = []
    total = 0
    while total < length:
        n_rec = int(rng.integers(2, 5))
        recs = [_record(rng) for _ in range(n_rec)]
        seg = b"".join(r[2] for r in recs)
        k, v, _ = recs[int(rng.integers(n_rec))]
        seg += b"?" + k + b"=" + v + b"."
        parts.append(seg)
        total += len(seg)
    return np.frombuffer(b"".join(parts)[:length], dtype=np.uint8).copy()


def gen_patterns(rng: np.random.Generator, length: int) -> np.ndarray:
    """Copy / repetition / majority patterns (train-only stream that makes
    the corresponding probes learnable)."""
    parts: list[bytes] = []
    total = 0
    while total < length:
        kind = int(rng.integers(3))
        if kind == 0:  # copy: |xyz|xyz|
            n = int(rng.integers(3, 7))
            s = bytes(rng.choice(_VAL_ALPHA[:26], size=n))
            seg = b"|" + s + b"|" + s + b"|"
        elif kind == 1:  # repetition: aaaa...
            c = bytes([int(rng.choice(_VAL_ALPHA[:26]))])
            seg = c * int(rng.integers(4, 9)) + b" "
        else:  # majority: AABAB>A
            n = int(rng.integers(5, 10))
            a, b = b"A", b"B"
            na = int(rng.integers(n // 2 + 1, n + 1))
            arr = np.array(list(a * na + b * (n - na)))
            rng.shuffle(arr)
            seg = arr.tobytes() + b">" + (a if na > n - na else b) + b" "
        parts.append(seg)
        total += len(seg)
    return np.frombuffer(b"".join(parts)[:length], dtype=np.uint8).copy()


def build_corpora(seed: int, train_len: int, valid_len: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    streams = {
        "markov": gen_markov(np.random.default_rng(seed + 1), train_len // 4),
        "zipf": gen_zipf(np.random.default_rng(seed + 2), train_len // 4),
        "template": gen_template(np.random.default_rng(seed + 3), train_len // 4),
        "patterns": gen_patterns(np.random.default_rng(seed + 4), train_len // 4),
    }
    # Train: interleave 256-byte chunks of all four streams.
    chunk = 256
    n_chunks = min(len(s) for s in streams.values()) // chunk
    pieces = []
    for c in range(n_chunks):
        for s in streams.values():
            pieces.append(s[c * chunk:(c + 1) * chunk])
    train = np.concatenate(pieces)
    rng_v = seed + 100
    return {
        "train": train,
        "valid_markov": gen_markov(np.random.default_rng(rng_v + 1), valid_len),
        "valid_zipf": gen_zipf(np.random.default_rng(rng_v + 2), valid_len),
        "valid_template": gen_template(np.random.default_rng(rng_v + 3), valid_len),
    }


# ----------------------------------------------------------------------
# Zero-shot probes: each item is {"context": bytes, "choices": [bytes...],
# "answer": int}. Scored by total logprob of choice continuation.
# ----------------------------------------------------------------------

def _probe_bigram(rng, table_rng, n_items):
    """Most likely next character under the markov table (order-1)."""
    k = len(_MARKOV_SYMS)
    table = _markov_table(table_rng, k)
    items = []
    ctx_src = gen_markov(np.random.default_rng(7), 64 * n_items)
    for i in range(n_items):
        ctx = ctx_src[i * 64:(i + 1) * 64]
        last = int(np.where(_MARKOV_SYMS == ctx[-1])[0][0])
        order = np.argsort(-table[last])
        correct = _MARKOV_SYMS[order[0]:order[0] + 1].tobytes()
        distract = [_MARKOV_SYMS[order[-j]:order[-j] + 1].tobytes() for j in (1, 2, 3)]
        choices = [correct] + distract
        perm = rng.permutation(4)
        items.append({"context": ctx.tobytes(),
                      "choices": [choices[p] for p in perm],
                      "answer": int(np.where(perm == 0)[0][0])})
    return items


def _probe_word_completion(rng, n_items):
    lex = _lexicon(np.random.default_rng(2), 500)
    long_words = [w for w in lex if len(w) >= 5][:200]
    items = []
    for _ in range(n_items):
        w = long_words[int(rng.integers(len(long_words)))]
        cut = len(w) - 2
        correct = w[cut:]
        distract = []
        while len(distract) < 3:
            d = bytes(rng.choice(_VAL_ALPHA[:26], size=2))
            if d != correct:
                distract.append(d)
        choices = [correct] + distract
        perm = rng.permutation(4)
        items.append({"context": b" " + w[:cut],
                      "choices": [choices[p] for p in perm],
                      "answer": int(np.where(perm == 0)[0][0])})
    return items


def _probe_retrieval(rng, n_items):
    items = []
    for _ in range(n_items):
        recs = [_record(rng) for _ in range(3)]
        ctx = b"".join(r[2] for r in recs)
        k, v, _ = recs[int(rng.integers(3))]
        ctx += b"?" + k + b"="
        others = [r[1] for r in recs if r[1] != v][:2]
        rand_v = bytes(rng.choice(_VAL_ALPHA, size=4))
        choices = [v] + others + [rand_v]
        choices = choices[:4]
        perm = rng.permutation(len(choices))
        items.append({"context": ctx,
                      "choices": [choices[p] for p in perm],
                      "answer": int(np.where(perm == 0)[0][0])})
    return items


def _probe_copy(rng, n_items):
    items = []
    for _ in range(n_items):
        n = int(rng.integers(3, 7))
        s = bytes(rng.choice(_VAL_ALPHA[:26], size=n))
        ctx = b"|" + s + b"|" + s[:n - 2]
        correct = s[n - 2:]
        distract = []
        while len(distract) < 3:
            d = bytes(rng.choice(_VAL_ALPHA[:26], size=2))
            if d != correct:
                distract.append(d)
        choices = [correct] + distract
        perm = rng.permutation(4)
        items.append({"context": ctx,
                      "choices": [choices[p] for p in perm],
                      "answer": int(np.where(perm == 0)[0][0])})
    return items


def _probe_majority(rng, n_items):
    items = []
    for _ in range(n_items):
        n = int(rng.integers(5, 10))
        na = int(rng.integers(n // 2 + 1, n + 1))
        arr = np.array(list(b"A" * na + b"B" * (n - na)))
        rng.shuffle(arr)
        correct = b"A" if na > n - na else b"B"
        items.append({"context": arr.tobytes() + b">",
                      "choices": [b"A", b"B"],
                      "answer": 0 if correct == b"A" else 1})
    return items


def _probe_repetition(rng, n_items):
    items = []
    for _ in range(n_items):
        c = bytes([int(rng.choice(_VAL_ALPHA[:26]))])
        d = bytes([int(rng.choice(_VAL_ALPHA[:26]))])
        reps = int(rng.integers(4, 8))
        choices = [c, d] if c != d else [c, b"z" if c != b"z" else b"y"]
        items.append({"context": c * reps,
                      "choices": choices,
                      "answer": 0})
    return items


def _probe_delimiter(rng, n_items):
    """After a 4-char value in a record, ';' must follow."""
    items = []
    for _ in range(n_items):
        k, v, rec = _record(rng)
        ctx = rec + k + b":" + v
        items.append({"context": ctx,
                      "choices": [b";", b":", b"?", b"a"],
                      "answer": 0})
    return items


def _probe_query_marker(rng, n_items):
    """Records end with a '?K=' query; after '?' comes a seen key."""
    items = []
    for _ in range(n_items):
        recs = [_record(rng) for _ in range(3)]
        ctx = b"".join(r[2] for r in recs) + b"?"
        k = recs[int(rng.integers(3))][0]
        fake = bytes(rng.choice(_KEY_ALPHA, size=2)) + b"5"
        choices = [k, fake]
        perm = rng.permutation(2)
        items.append({"context": ctx,
                      "choices": [choices[p] for p in perm],
                      "answer": int(np.where(perm == 0)[0][0])})
    return items


def build_probes(seed: int, n_items: int = 100) -> dict[str, list]:
    rng = np.random.default_rng(seed)
    return {
        "bigram": _probe_bigram(rng, np.random.default_rng(seed + 1), n_items),
        "word_completion": _probe_word_completion(rng, n_items),
        "retrieval": _probe_retrieval(rng, n_items),
        "copy": _probe_copy(rng, n_items),
        "majority": _probe_majority(rng, n_items),
        "repetition": _probe_repetition(rng, n_items),
        "delimiter": _probe_delimiter(rng, n_items),
        "query_marker": _probe_query_marker(rng, n_items),
    }


def probes_to_json(probes: dict[str, list]) -> str:
    """Token-level JSON (lists of ints) so the Rust side needs no decoding."""
    enc = {
        task: [
            {
                "context": list(item["context"]),
                "choices": [list(c) for c in item["choices"]],
                "answer": item["answer"],
            }
            for item in items
        ]
        for task, items in probes.items()
    }
    return json.dumps(enc)
