"""AOT build: lower L1+L2 to HLO text artifacts and prepare all runtime data.

Run ONCE by `make artifacts` (python -m compile.aot --out ../artifacts).
After this the Rust binary is self-contained; python never runs again.

Outputs (under --out):
  hlo/dykstra_m{M}_b{B}.hlo.txt   batched Dykstra solver (per M, per bucket;
                                  N and tau are runtime scalar inputs)
  hlo/model_fwd.hlo.txt           (weights..., tokens) -> (loss, logprobs)
  hlo/model_grad.hlo.txt          (weights..., masks..., tokens) -> (loss, grads...)
  hlo/calib.hlo.txt               (weights..., tokens) -> per-site Gram matrices
  weights/<name>.npy              trained tiny-transformer weights
  corpus/*.bin                    u8 token streams (train + 3 validation)
  probes/probes.json              zero-shot probe items (token ids)
  manifest.json                   everything the Rust coordinator needs

HLO *text* is the interchange format: jax>=0.5 serialized protos use 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import model as model_mod
from .kernels.dykstra import dykstra_pallas
from .kernels.ref import dykstra_ref

# T=100: quality saturates by 100 sweeps for every M <= 32 at the default
# tau (see EXPERIMENTS.md §Perf iteration ablation); halves artifact runtime.
DYKSTRA_ITERS = 100
DYKSTRA_MS = (4, 8, 16, 32)
# Two batch buckets per M: large for throughput, small for low-padding tails.
BUCKET_ELEMS = (1 << 20, 1 << 16)
FWD_BATCH = 8
GRAD_BATCH = 4
CALIB_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


# ----------------------------------------------------------------------
# Dykstra artifacts
# ----------------------------------------------------------------------

def lower_dykstra(out: str) -> list[dict]:
    entries = []
    for m in DYKSTRA_MS:
        for elems in BUCKET_ELEMS:
            bucket = max(64, elems // (m * m))
            fn = functools.partial(dykstra_pallas, iters=DYKSTRA_ITERS)
            lowered = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((bucket, m, m), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.float32),
            )
            rel = f"hlo/dykstra_m{m}_b{bucket}.hlo.txt"
            _write(os.path.join(out, rel), to_hlo_text(lowered))
            entries.append(
                {"m": m, "bucket": bucket, "iters": DYKSTRA_ITERS, "file": rel}
            )
            print(f"  dykstra m={m} bucket={bucket} -> {rel}")
    return entries


def selfcheck_dykstra() -> None:
    """Kernel-vs-oracle gate: refuse to emit artifacts if L1 drifts."""
    rng = np.random.default_rng(0)
    for m, n in ((4, 2), (8, 4), (16, 8), (32, 16)):
        absw = jnp.asarray(np.abs(rng.standard_normal((8, m, m))), jnp.float32)
        tau = jnp.float32(120.0 / float(jnp.max(absw)))
        logn = jnp.float32(np.log(n))
        got = dykstra_pallas(absw, tau, logn, iters=60)
        want = dykstra_ref(absw, tau, logn, iters=60)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, f"dykstra selfcheck failed m={m}: {err}"
    print("  dykstra selfcheck OK")


# ----------------------------------------------------------------------
# Model artifacts
# ----------------------------------------------------------------------

def _weight_specs(cfg) -> list[jax.ShapeDtypeStruct]:
    shapes = model_mod.weight_shapes(cfg)
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32)
            for n in model_mod.weight_names(cfg)]


def lower_model(out: str, cfg) -> dict:
    wspecs = _weight_specs(cfg)
    tok = lambda b: jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32)

    fwd = lambda ws, t: model_mod.loss_and_logprobs(cfg, ws, t)
    lowered = jax.jit(fwd).lower(wspecs, tok(FWD_BATCH))
    _write(os.path.join(out, "hlo/model_fwd.hlo.txt"), to_hlo_text(lowered))
    print("  model_fwd lowered")

    shapes = model_mod.weight_shapes(cfg)
    mspecs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32)
              for n in model_mod.prunable_names(cfg)]
    grad = lambda ws, ms, t: model_mod.finetune_loss_and_grads(cfg, ws, ms, t)
    lowered = jax.jit(grad).lower(wspecs, mspecs, tok(GRAD_BATCH))
    _write(os.path.join(out, "hlo/model_grad.hlo.txt"), to_hlo_text(lowered))
    print("  model_grad lowered")

    calib = lambda ws, t: model_mod.calibration_grams(cfg, ws, t)
    lowered = jax.jit(calib).lower(wspecs, tok(CALIB_BATCH))
    _write(os.path.join(out, "hlo/calib.hlo.txt"), to_hlo_text(lowered))
    print("  calib lowered")

    return {
        "model_fwd": {"file": "hlo/model_fwd.hlo.txt", "batch": FWD_BATCH,
                      "seq": cfg.seq_len},
        "model_grad": {"file": "hlo/model_grad.hlo.txt", "batch": GRAD_BATCH,
                       "seq": cfg.seq_len},
        "calib": {"file": "hlo/calib.hlo.txt", "batch": CALIB_BATCH,
                  "seq": cfg.seq_len},
    }


# ----------------------------------------------------------------------
# Build-time training of the tiny target model (LLaMA stand-in)
# ----------------------------------------------------------------------

def train_model(cfg, corpora: dict, steps: int, seed: int):
    key = jax.random.PRNGKey(seed)
    weights = model_mod.init_weights(key, cfg)
    train = corpora["train"].astype(np.int32)
    batch, t = GRAD_BATCH, cfg.seq_len

    lr_peak, warmup = 1e-3, 20
    b1, b2, eps = 0.9, 0.95, 1e-8
    m_state = [jnp.zeros_like(w) for w in weights]
    v_state = [jnp.zeros_like(w) for w in weights]

    @jax.jit
    def step(ws, m_s, v_s, toks, lr, t_step):
        loss, grads = jax.value_and_grad(
            lambda w: model_mod.train_loss(cfg, w, toks))(ws)
        new_ws, new_m, new_v = [], [], []
        for w, g, m, v in zip(ws, grads, m_s, v_s):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** t_step)
            vhat = v / (1 - b2 ** t_step)
            new_ws.append(w - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(m)
            new_v.append(v)
        return new_ws, new_m, new_v, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    loss_val = float("nan")
    for s in range(1, steps + 1):
        starts = rng.integers(0, len(train) - t - 1, size=batch)
        toks = np.stack([train[a:a + t] for a in starts])
        lr = lr_peak * min(1.0, s / warmup)
        weights, m_state, v_state, loss = step(
            weights, m_state, v_state, jnp.asarray(toks), lr, s)
        if s == 1 or s % 25 == 0:
            loss_val = float(loss)
            print(f"  train step {s}/{steps} loss={loss_val:.4f} "
                  f"({time.time() - t0:.0f}s)")
    return [np.asarray(w) for w in weights], loss_val


# ----------------------------------------------------------------------

def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--seed", type=int, default=17)
    p.add_argument("--train-steps", type=int,
                   default=int(os.environ.get("TSENOR_TRAIN_STEPS", "300")))
    p.add_argument("--train-len", type=int, default=1 << 19)
    p.add_argument("--valid-len", type=int, default=1 << 15)
    args = p.parse_args()
    out = args.out
    cfg = model_mod.Config()

    print("[1/5] corpora + probes")
    corpora = corpus_mod.build_corpora(args.seed, args.train_len, args.valid_len)
    os.makedirs(os.path.join(out, "corpus"), exist_ok=True)
    corpus_meta = {}
    for name, arr in corpora.items():
        rel = f"corpus/{name}.bin"
        arr.astype(np.uint8).tofile(os.path.join(out, rel))
        corpus_meta[name] = {"file": rel, "len": int(len(arr))}
    probes = corpus_mod.build_probes(args.seed + 50)
    os.makedirs(os.path.join(out, "probes"), exist_ok=True)
    with open(os.path.join(out, "probes/probes.json"), "w") as f:
        f.write(corpus_mod.probes_to_json(probes))

    print("[2/5] dykstra selfcheck + lowering")
    selfcheck_dykstra()
    dykstra_entries = lower_dykstra(out)

    print(f"[3/5] training target model ({args.train_steps} steps)")
    weights, final_loss = train_model(cfg, corpora, args.train_steps, args.seed)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)
    names = model_mod.weight_names(cfg)
    shapes = model_mod.weight_shapes(cfg)
    prunable = set(model_mod.prunable_names(cfg))
    weight_meta = []
    for name, w in zip(names, weights):
        rel = f"weights/{name}.npy"
        np.save(os.path.join(out, rel), w.astype(np.float32))
        weight_meta.append({"name": name, "shape": list(shapes[name]),
                            "prunable": name in prunable, "file": rel})

    print("[4/5] model artifacts")
    model_entries = lower_model(out, cfg)

    print("[5/5] manifest")
    manifest = {
        "version": 1,
        "seed": args.seed,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len, "rms_eps": cfg.rms_eps,
        },
        "weights": weight_meta,
        "prunable": sorted(prunable),
        "gram_sites": model_mod.gram_sites(cfg),
        "artifacts": {"dykstra": dykstra_entries, **model_entries},
        "corpora": corpus_meta,
        "probes": "probes/probes.json",
        "train_meta": {"steps": args.train_steps, "final_loss": final_loss},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("artifacts complete:", out)


if __name__ == "__main__":
    main()
