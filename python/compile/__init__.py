"""Build-time compile path: L1 Pallas kernels + L2 JAX model -> HLO artifacts.

Nothing in this package is imported at runtime; the Rust coordinator only
consumes the artifacts/ directory produced by `python -m compile.aot`.
"""
