"""L1 Pallas kernel: batched entropy-regularized Dykstra solver.

The paper's Algorithm 1 as a single fused kernel over a (B, M, M) batch of
blocks. All state (log S, log Q) lives in the kernel's VMEM tile for the
whole iteration loop, so HBM traffic is exactly one read of |W| and one
write of S per block — the schedule the paper gets from a fused PyTorch
GPU graph, expressed here with a BlockSpec grid over the batch dimension.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the inner reductions are
M-length logsumexps (M <= 32) on the minor axes — pure VPU work, no MXU —
so the tile size TB is chosen to saturate vector lanes while keeping
2 * TB * M * M * 4 bytes (log_s + log_q) comfortably under VMEM.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust CPU client (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logsumexp(x: jax.Array, axis: int) -> jax.Array:
    """Stable logsumexp, keepdims=True (pallas-safe: no jax.nn dependency)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    return m + jnp.log(jnp.sum(jnp.exp(x - m), axis=axis, keepdims=True))


def _dykstra_kernel(scal_ref, absw_ref, out_ref, *, iters: int):
    """One grid step: solve a (TB, M, M) tile of blocks to completion.

    scal_ref: (2,) f32 = [tau, log(N)] runtime scalars (shared by all
      blocks in the call so a single artifact serves every N of a given M).
    """
    tau = scal_ref[0]
    logn = scal_ref[1]
    log_s = tau * absw_ref[...]
    log_q = jnp.zeros_like(log_s)

    def body(_, carry):
        log_s, log_q = carry
        # C1: rows of every block sum to N.
        log_s = log_s - (_logsumexp(log_s, axis=2) - logn)
        # C2: columns of every block sum to N.
        log_s = log_s - (_logsumexp(log_s, axis=1) - logn)
        # C3: capacity S <= 1, with Dykstra dual variable Q.
        log_tmp = log_s + log_q
        log_s_new = jnp.minimum(log_tmp, 0.0)
        log_q = log_tmp - log_s_new
        return log_s_new, log_q

    log_s, _ = jax.lax.fori_loop(0, iters, body, (log_s, log_q))
    out_ref[...] = jnp.exp(log_s)


def _tile_batch(batch: int, m: int) -> int:
    """Pick TB so a tile holds ~64K elements (VMEM budget per DESIGN.md)."""
    target = 65536 // (m * m)
    tb = max(1, min(batch, target))
    while batch % tb != 0:  # grid must divide the batch evenly
        tb -= 1
    return tb


@functools.partial(jax.jit, static_argnames=("iters",))
def dykstra_pallas(
    absw: jax.Array, tau: jax.Array, logn: jax.Array, iters: int = 200
) -> jax.Array:
    """Solve problem (4) for every block. See ref.dykstra_ref for semantics.

    Args:
      absw: (B, M, M) f32 block scores.
      tau, logn: scalars (runtime inputs -> one artifact per M, any N/tau).
      iters: static sweep count.

    Returns: (B, M, M) fractional solution in [0, 1].
    """
    b, m, _ = absw.shape
    tb = _tile_batch(b, m)
    scal = jnp.stack(
        [jnp.asarray(tau, jnp.float32).reshape(()), jnp.asarray(logn, jnp.float32).reshape(())]
    )
    kernel = functools.partial(_dykstra_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),  # scalars broadcast to all steps
            pl.BlockSpec((tb, m, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, m, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m, m), jnp.float32),
        interpret=True,
    )(scal, absw.astype(jnp.float32))
