"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `*_ref` counterpart to float tolerance (pytest + hypothesis
in python/tests/). They are also used by aot.py's self-checks before an
artifact is written.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dykstra_ref(
    absw: jax.Array, tau: jax.Array, logn: jax.Array, iters: int
) -> jax.Array:
    """Entropy-regularized transposable-N:M relaxation via Dykstra.

    Solves, for every M x M block b independently,

        max <S, absw[b]> + (1/tau) H(S)
        s.t. S @ 1 = N, S^T @ 1 = N, 0 <= S <= 1

    by KL/Bregman projections onto the three constraint sets (Algorithm 1
    of the paper), carried out in log-space for numerical stability
    (Appendix A.2).

    Args:
      absw: (B, M, M) nonneg block scores |W|.
      tau:  scalar (or (1,)) regularization strength.
      logn: scalar (or (1,)) log(N) target row/col log-mass.
      iters: number of Dykstra sweeps (static).

    Returns:
      (B, M, M) fractional solution in [0, 1].
    """
    tau = jnp.asarray(tau, jnp.float32).reshape(())
    logn = jnp.asarray(logn, jnp.float32).reshape(())
    log_s = tau * absw.astype(jnp.float32)
    log_q = jnp.zeros_like(log_s)

    def body(_, carry):
        log_s, log_q = carry
        # Projection onto C1 (row sums = N): row-wise log normalization.
        log_s = log_s - (jax.nn.logsumexp(log_s, axis=2, keepdims=True) - logn)
        # Projection onto C2 (col sums = N).
        log_s = log_s - (jax.nn.logsumexp(log_s, axis=1, keepdims=True) - logn)
        # Projection onto C3 (S <= 1) with Dykstra dual correction.
        log_tmp = log_s + log_q
        log_s_new = jnp.minimum(log_tmp, 0.0)
        log_q = log_tmp - log_s_new
        return log_s_new, log_q

    log_s, _ = jax.lax.fori_loop(0, iters, body, (log_s, log_q))
    return jnp.exp(log_s)


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """y = x @ (w * mask). Oracle for the masked-GEMM Pallas kernel."""
    return x.astype(jnp.float32) @ (w * mask).astype(jnp.float32)


def greedy_round_ref(scores, n: int):
    """Simple (non-vectorized, numpy) greedy rounding oracle.

    Used only in tests as a feasibility/objective sanity baseline for the
    Rust rounding implementation; NOT part of any artifact.
    Returns a (M, M) 0/1 mask with row/col sums <= n (== n when feasible).
    """
    import numpy as np

    scores = np.asarray(scores)
    m = scores.shape[0]
    order = np.argsort(-scores, axis=None)
    mask = np.zeros((m, m), dtype=np.float32)
    rows = np.zeros(m, dtype=np.int64)
    cols = np.zeros(m, dtype=np.int64)
    for flat in order:
        i, j = divmod(int(flat), m)
        if rows[i] < n and cols[j] < n:
            mask[i, j] = 1.0
            rows[i] += 1
            cols[j] += 1
    return mask
