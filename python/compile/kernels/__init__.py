"""L1 Pallas kernels (interpret=True) and their pure-jnp oracles."""

from .dykstra import dykstra_pallas  # noqa: F401
from .masked_matmul import masked_matmul  # noqa: F401
from . import ref  # noqa: F401
