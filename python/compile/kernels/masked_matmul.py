"""L1 Pallas kernel: masked GEMM  y = x @ (w * mask)  with analytic VJP.

Used by the L2 fine-tuning graph: the forward pass applies the (frozen)
transposable N:M mask inside the kernel, and the custom VJP implements the
backward pass the way transposable sparsity makes possible — the gradient
w.r.t. x multiplies by the *transposed* masked weights, which is itself an
N:M-sparse product because the mask is transposable (the paper's whole
point). Registering the VJP analytically also sidesteps differentiating
through pallas interpret mode.

TPU adaptation: classic (i, j) output tiling with a full-K contraction per
tile — (bm, K) x (K, bn) MXU matmuls from VMEM; mask application fuses as
a VPU elementwise op on the weight tile before it enters the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, mask_ref, o_ref):
    wm = w_ref[...] * mask_ref[...]
    o_ref[...] = jnp.dot(x_ref[...], wm, preferred_element_type=jnp.float32)


def _pick(dim: int, pref: int) -> int:
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


def _masked_matmul_fwd_impl(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    n, k = x.shape
    k2, m = w.shape
    assert k == k2, (x.shape, w.shape)
    bn = _pick(n, 128)
    bm = _pick(m, 128)
    return pl.pallas_call(
        _mm_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bm), lambda i, j: (0, j)),
            pl.BlockSpec((k, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), mask.astype(jnp.float32))


@jax.custom_vjp
def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """y = x @ (w * mask); mask is constant (no gradient)."""
    return _masked_matmul_fwd_impl(x, w, mask)


def _fwd(x, w, mask):
    return _masked_matmul_fwd_impl(x, w, mask), (x, w, mask)


def _bwd(res, g):
    x, w, mask = res
    wm = w * mask
    dx = g @ wm.T  # transposable N:M: this is itself an N:M-sparse product
    dw = (x.T @ g) * mask  # gradient only flows to kept weights
    return dx, dw, None


masked_matmul.defvjp(_fwd, _bwd)
