"""L2: decoder-only transformer in JAX (the pruning target / eval model).

This is the stand-in for LLaMA-3.2 in the paper's experiments (DESIGN.md
§Substitutions): same structural layout per block (RMSNorm -> q/k/v/o
attention -> RMSNorm -> SiLU-gated MLP, tied embedding head), scaled to a
few million parameters so the whole pipeline runs on one CPU core.

All entry points take weights as a *flat list* in the canonical order of
`weight_names(cfg)` so the Rust coordinator can feed pruned weights
positionally through PJRT without any pytree logic on the Rust side.

The fine-tuning graph (`finetune_loss`) routes every prunable linear
through the L1 `masked_matmul` Pallas kernel, whose custom VJP encodes the
transposable-sparsity backward pass (grad x = g @ (W*S)^T is itself an
N:M-sparse product — the property the paper exists to enable).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.masked_matmul import masked_matmul


@dataclasses.dataclass(frozen=True)
class Config:
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Per-layer 2D linear weights, in order. All are prunable (divisible by 32).
LAYER_LINEARS = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")
LAYER_NORMS = ("ln1", "ln2")


def weight_names(cfg: Config) -> list[str]:
    """Canonical flat weight order shared with the Rust manifest."""
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.{p}" for p in ("ln1", "wq", "wk", "wv", "wo",
                                              "ln2", "wgate", "wup", "wdown")]
    names.append("lnf")
    return names


def weight_shapes(cfg: Config) -> dict[str, tuple[int, ...]]:
    d, f = cfg.d_model, cfg.d_ff
    shapes: dict[str, tuple[int, ...]] = {
        "embed": (cfg.vocab, d),
        "pos": (cfg.seq_len, d),
        "lnf": (d,),
    }
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "ln1"] = (d,)
        shapes[p + "ln2"] = (d,)
        shapes[p + "wq"] = (d, d)
        shapes[p + "wk"] = (d, d)
        shapes[p + "wv"] = (d, d)
        shapes[p + "wo"] = (d, d)
        shapes[p + "wgate"] = (d, f)
        shapes[p + "wup"] = (d, f)
        shapes[p + "wdown"] = (f, d)
    return shapes


def prunable_names(cfg: Config) -> list[str]:
    return [n for n in weight_names(cfg)
            if n.split(".")[-1] in LAYER_LINEARS]


def init_weights(key: jax.Array, cfg: Config) -> list[jax.Array]:
    """Scaled-normal init, flat canonical order."""
    names = weight_names(cfg)
    shapes = weight_shapes(cfg)
    ws = []
    for name in names:
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            ws.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            scale = 0.02 if name in ("embed", "pos") else fan_in ** -0.5
            ws.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return ws


def _unflatten(cfg: Config, weights: Sequence[jax.Array]) -> dict[str, jax.Array]:
    return dict(zip(weight_names(cfg), weights))


def _rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _attention(cfg: Config, x: jax.Array, q, k, v) -> jax.Array:
    """Causal multi-head attention. q,k,v: (B, T, d) already projected."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(z):
        return z.reshape(b, t, h, hd).transpose(0, 2, 1, 3)  # (B,H,T,hd)

    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((t, t), jnp.bool_))
    att = jnp.where(causal, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def _block(cfg: Config, w: dict, i: int, h: jax.Array, linear, captures=None):
    """One transformer block; `linear(x, name)` performs the projection."""
    p = f"layers.{i}."
    x1 = _rmsnorm(h, w[p + "ln1"], cfg.rms_eps)
    if captures is not None:
        captures[p + "attn_in"] = x1
    q = linear(x1, p + "wq")
    k = linear(x1, p + "wk")
    v = linear(x1, p + "wv")
    ao = _attention(cfg, x1, q, k, v)
    if captures is not None:
        captures[p + "attn_out"] = ao
    h = h + linear(ao, p + "wo")
    x2 = _rmsnorm(h, w[p + "ln2"], cfg.rms_eps)
    if captures is not None:
        captures[p + "mlp_in"] = x2
    g = jax.nn.silu(linear(x2, p + "wgate")) * linear(x2, p + "wup")
    if captures is not None:
        captures[p + "mlp_down"] = g
    h = h + linear(g, p + "wdown")
    return h


def _forward(cfg: Config, weights, tokens, masks=None, use_pallas=False,
             captures=None):
    """Returns logits (B, T, V). masks: dict name->mask for prunable linears."""
    w = _unflatten(cfg, weights)
    b, t = tokens.shape

    def linear(x, name):
        wm = w[name]
        if masks is not None and name in masks:
            if use_pallas:
                flat = x.reshape(-1, x.shape[-1])
                return masked_matmul(flat, wm, masks[name]).reshape(
                    *x.shape[:-1], wm.shape[1])
            wm = wm * masks[name]
        return x @ wm

    h = w["embed"][tokens] + w["pos"][:t][None, :, :]
    for i in range(cfg.n_layers):
        h = _block(cfg, w, i, h, linear, captures)
    h = _rmsnorm(h, w["lnf"], cfg.rms_eps)
    return h @ w["embed"].T  # tied output head


def forward_logits(cfg: Config, weights, tokens):
    return _forward(cfg, weights, tokens)


def loss_and_logprobs(cfg: Config, weights, tokens):
    """AOT entry `model_fwd`: next-token loss + per-position logprobs.

    Returns (mean_loss scalar, logprobs (B, T-1)) where logprobs[b, t] is
    log p(tokens[b, t+1] | tokens[b, :t+1]) — everything perplexity and the
    zero-shot probes need.
    """
    logits = _forward(cfg, weights, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    tok_logp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(tok_logp), tok_logp


def train_loss(cfg: Config, weights, tokens):
    """Dense training loss (used only at build time by aot.py)."""
    loss, _ = loss_and_logprobs(cfg, weights, tokens)
    return loss


def finetune_loss(cfg: Config, weights, masks_flat, tokens):
    """Masked fine-tune loss; prunable linears go through the L1 kernel."""
    masks = dict(zip(prunable_names(cfg), masks_flat))
    logits = _forward(cfg, weights, tokens, masks=masks, use_pallas=True)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    tok_logp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(tok_logp)


def finetune_loss_and_grads(cfg: Config, weights, masks_flat, tokens):
    """AOT entry `model_grad`: (loss, grads w.r.t. every weight tensor)."""
    loss, grads = jax.value_and_grad(
        lambda ws: finetune_loss(cfg, ws, masks_flat, tokens))(list(weights))
    return loss, *grads


# Calibration sites: inputs feeding each group of prunable linears.
def gram_sites(cfg: Config) -> list[dict]:
    """Site metadata mirrored into the manifest for the Rust side."""
    sites = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        sites.append({"name": p + "attn_in", "dim": cfg.d_model,
                      "weights": [p + "wq", p + "wk", p + "wv"]})
        sites.append({"name": p + "attn_out", "dim": cfg.d_model,
                      "weights": [p + "wo"]})
        sites.append({"name": p + "mlp_in", "dim": cfg.d_model,
                      "weights": [p + "wgate", p + "wup"]})
        sites.append({"name": p + "mlp_down", "dim": cfg.d_ff,
                      "weights": [p + "wdown"]})
    return sites


def calibration_grams(cfg: Config, weights, tokens):
    """AOT entry `calib`: (loss, Gram matrix X^T X per site). Layer-wise
    pruning needs only H = X^T X + lambda I, never raw activations. The
    loss output (a) sanity-checks calibration batches and (b) keeps every
    weight live so XLA does not DCE parameters out of the artifact
    signature (lnf / the last wdown feed only the logits)."""
    captures: dict[str, jax.Array] = {}
    logits = _forward(cfg, weights, tokens, captures=captures)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    tok_logp = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(tok_logp)
    grams = []
    for site in gram_sites(cfg):
        x = captures[site["name"]]
        flat = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        grams.append(flat.T @ flat)
    return (loss, *grams)
