//! END-TO-END DRIVER (DESIGN.md §5): prune the trained tiny transformer to
//! transposable 16:32 sparsity with TSENOR+ALPS through the full
//! three-layer stack, then evaluate perplexity on the three held-out
//! corpora and all eight zero-shot probes. Prints a Table-2-shaped row.
//!
//!   make artifacts && cargo run --release --example prune_transformer
//!
//! Everything at runtime is Rust: calibration activations come from the
//! AOT calib artifact via PJRT, masks come from the XLA Dykstra artifact
//! (+ Rust rounding), evaluation runs the AOT model_fwd artifact.

use tsenor::coordinator::batcher::XlaSolver;
use tsenor::coordinator::metrics::Metrics;
use tsenor::coordinator::pipeline::{self, Framework, MaskBackend, Structure};
use tsenor::masks::solver::SolveCfg;
use tsenor::masks::NmPattern;
use tsenor::runtime::client::ModelRuntime;
use tsenor::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    anyhow::ensure!(
        root.join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    let manifest = Manifest::load(root)?;
    let engine = Engine::new(&manifest)?;
    let rt = ModelRuntime::new(&engine, &manifest);
    let pattern = NmPattern::new(16, 32);

    println!("=== TSENOR+ALPS end-to-end: transposable {pattern} on the trained transformer ===");
    println!(
        "model: {} layers, d={}, {} prunable matrices | platform: {}",
        manifest.model.n_layers,
        manifest.model.d_model,
        manifest.prunable_names().len(),
        engine.platform()
    );

    // Dense baseline first.
    let dense_weights = manifest.load_weights()?;
    let dense_ppl = tsenor::eval::perplexity::perplexity_suite(&rt, &dense_weights, Some(12))?;
    let probes = tsenor::data::probes::load(&manifest.root.join(&manifest.probes_file))?;
    let (dense_zs, dense_zs_mean) =
        tsenor::eval::zeroshot::score_all(&rt, &dense_weights, &probes, 50)?;

    // Prune: TSENOR masks via the XLA artifact, ALPS layer-wise ADMM.
    let xla = XlaSolver::new(&engine, &manifest, SolveCfg::default());
    let backend = MaskBackend::Xla(&xla);
    let mut metrics = Metrics::new();
    let t0 = std::time::Instant::now();
    let state = pipeline::run(
        &rt,
        Framework::Alps,
        Structure::Transposable,
        pattern,
        &backend,
        8,
        Some(12),
        &mut metrics,
    )?;
    let prune_secs = t0.elapsed().as_secs_f64();
    let (zs, zs_mean) = tsenor::eval::zeroshot::score_all(&rt, &state.weights, &probes, 50)?;

    println!(
        "\npruned in {prune_secs:.1}s | sparsity {:.3} | {} dykstra blocks solved ({} padded) | {:.2}s in PJRT",
        state.sparsity(),
        xla.solved_blocks.get(),
        xla.padded_blocks.get(),
        engine.exec_nanos.get() as f64 / 1e9
    );

    // Table-2-shaped report.
    println!("\n{:<22}{:>10}{:>10}{:>10}  {}", "", "markov", "zipf", "template", "zero-shot tasks ->");
    let ppl_row = |label: &str, ppl: &std::collections::BTreeMap<String, f64>| {
        println!(
            "{:<22}{:>10.3}{:>10.3}{:>10.3}",
            label,
            ppl.get("valid_markov").unwrap_or(&f64::NAN),
            ppl.get("valid_zipf").unwrap_or(&f64::NAN),
            ppl.get("valid_template").unwrap_or(&f64::NAN)
        );
    };
    ppl_row("dense (ppl)", &dense_ppl);
    let pruned_ppl: std::collections::BTreeMap<String, f64> = manifest
        .corpora
        .keys()
        .filter(|n| *n != "train")
        .filter_map(|n| metrics.get(&format!("ppl_{n}")).map(|p| (n.clone(), p)))
        .collect();
    ppl_row("tsenor+alps 16:32", &pruned_ppl);

    println!("\n{:<18}{:>8}{:>8}", "zero-shot task", "dense", "pruned");
    for (task, acc) in &zs {
        println!("{:<18}{:>8.3}{:>8.3}", task, dense_zs[task], acc);
    }
    println!("{:<18}{:>8.3}{:>8.3}", "MEAN", dense_zs_mean, zs_mean);

    // Record layer-wise recon errors summary.
    let recon = metrics.to_json();
    if let Some(errors) = recon.get("layer_recon_error").and_then(|j| j.as_arr()) {
        let vals: Vec<f64> = errors.iter().filter_map(|e| e.as_f64()).collect();
        let mean = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
        println!("\nmean layer recon error: {mean:.4} over {} layers", vals.len());
    }
    metrics.write(std::path::Path::new("artifacts/reports/prune_transformer.json"))?;
    println!("metrics -> artifacts/reports/prune_transformer.json");
    Ok(())
}
