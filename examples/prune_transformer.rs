//! END-TO-END DRIVER (DESIGN.md §5): prune the trained tiny transformer to
//! transposable 16:32 sparsity with TSENOR+ALPS through the full
//! three-layer stack, then evaluate perplexity on the three held-out
//! corpora and all eight zero-shot probes. Prints a Table-2-shaped row.
//!
//!   make artifacts && cargo run --release --example prune_transformer
//!
//! Everything at runtime is Rust: the run is one `PruneSpec` + the XLA
//! `MaskOracle` — calibration activations come from the AOT calib
//! artifact via PJRT, masks from the XLA Dykstra artifact (+ Rust
//! rounding), evaluation runs the AOT model_fwd artifact.

use tsenor::coordinator::batcher::XlaSolver;
use tsenor::coordinator::metrics::Metrics;
use tsenor::coordinator::pipeline;
use tsenor::masks::solver::SolveCfg;
use tsenor::runtime::client::ModelRuntime;
use tsenor::runtime::{Engine, Manifest};
use tsenor::spec::{Framework, PruneSpec};

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    anyhow::ensure!(
        root.join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    let manifest = Manifest::load(root)?;
    let engine = Engine::new(&manifest)?;
    let rt = ModelRuntime::new(&engine, &manifest);

    let spec = PruneSpec::new(Framework::Alps)
        .pattern(16, 32)
        .calib_batches(8)
        .eval_batches(Some(12));
    let pattern = spec.pattern;

    println!("=== TSENOR+ALPS end-to-end: transposable {pattern} on the trained transformer ===");
    println!(
        "model: {} layers, d={}, {} prunable matrices | platform: {}",
        manifest.model.n_layers,
        manifest.model.d_model,
        manifest.prunable_names().len(),
        engine.platform()
    );

    // Dense baseline first.
    let dense_weights = manifest.load_weights()?;
    let dense_ppl = tsenor::eval::perplexity::perplexity_suite(&rt, &dense_weights, Some(12))?;
    let probes = tsenor::data::probes::load(&manifest.root.join(&manifest.probes_file))?;
    let (dense_zs, dense_zs_mean) =
        tsenor::eval::zeroshot::score_all(&rt, &dense_weights, &probes, 50)?;

    // Prune: TSENOR masks via the XLA oracle, ALPS layer-wise ADMM.
    let xla = XlaSolver::new(&engine, &manifest, SolveCfg::default());
    let mut metrics = Metrics::new();
    let report = pipeline::run(&rt, &spec, &xla, &mut metrics)?;
    let (zs, zs_mean) =
        tsenor::eval::zeroshot::score_all(&rt, &report.state.weights, &probes, 50)?;

    println!(
        "\npruned in {:.1}s | sparsity {:.3} | {} dykstra blocks solved ({} padded) | {:.2}s in PJRT",
        report.wall_secs,
        report.model_sparsity,
        report.oracle_stats.blocks_solved,
        report.oracle_stats.padded_blocks,
        engine.stats().exec_secs()
    );

    // Table-2-shaped report.
    println!("\n{:<22}{:>10}{:>10}{:>10}  {}", "", "markov", "zipf", "template", "zero-shot tasks ->");
    let ppl_row = |label: &str, ppl: &std::collections::BTreeMap<String, f64>| {
        println!(
            "{:<22}{:>10.3}{:>10.3}{:>10.3}",
            label,
            ppl.get("valid_markov").unwrap_or(&f64::NAN),
            ppl.get("valid_zipf").unwrap_or(&f64::NAN),
            ppl.get("valid_template").unwrap_or(&f64::NAN)
        );
    };
    ppl_row("dense (ppl)", &dense_ppl);
    ppl_row("tsenor+alps 16:32", &report.perplexity);

    println!("\n{:<18}{:>8}{:>8}", "zero-shot task", "dense", "pruned");
    for (task, acc) in &zs {
        println!("{:<18}{:>8.3}{:>8.3}", task, dense_zs[task], acc);
    }
    println!("{:<18}{:>8.3}{:>8.3}", "MEAN", dense_zs_mean, zs_mean);

    println!(
        "\nmean layer recon error: {:.4} over {} layers",
        report.mean_recon_error(),
        report.layers.len()
    );
    report.write(std::path::Path::new("artifacts/reports/prune_transformer.json"))?;
    println!("report -> artifacts/reports/prune_transformer.json");
    Ok(())
}
