//! Fig. 3 in miniature: relative error of every mask-generation method vs
//! the exact optimum, on blocks sampled from the TRAINED transformer
//! weights (when artifacts exist) or synthetic heavy-tail blocks.
//!
//!   cargo run --release --example solver_comparison

use tsenor::data::workload;
use tsenor::masks::solver::{self, Method, SolveCfg};
use tsenor::masks::{batch_objective, exact, relative_error, NmPattern};
use tsenor::runtime::Manifest;
use tsenor::util::tensor::Blocks;

fn sample_blocks(m: usize, count: usize) -> Blocks {
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() {
        let manifest = Manifest::load(root).unwrap();
        let weights = manifest.load_weights().unwrap();
        // paper Fig. 3: blocks sampled from real model weights
        let w = &weights["layers.0.wq"];
        return workload::sample_blocks(w, m, count, 7);
    }
    workload::heavy_tail_blocks(count, m, 7)
}

fn main() {
    let patterns = [
        NmPattern::new(4, 8),
        NmPattern::new(8, 16),
        NmPattern::new(16, 32),
        NmPattern::new(2, 8),
        NmPattern::new(4, 16),
        NmPattern::new(8, 32),
    ];
    let methods = [
        Method::Tsenor,
        Method::EntropySimple,
        Method::TwoApprox,
        Method::BiNm,
        Method::Max1000,
    ];
    let cfg = SolveCfg::default();

    println!("relative error vs optimal, 100 blocks per pattern (lower is better)\n");
    print!("{:<14}", "pattern");
    for m in &methods {
        print!("{:>12}", m.name());
    }
    println!();
    for pattern in &patterns {
        let scores = sample_blocks(pattern.m, 100);
        let (_, opt) = exact::solve_batch(&scores, pattern.n);
        print!("{:<14}", format!("{pattern}"));
        for method in &methods {
            let masks = solver::solve_blocks(*method, &scores, pattern.n, &cfg).unwrap();
            let rel = relative_error(opt, batch_objective(&masks, &scores));
            print!("{:>12.4}", rel);
        }
        println!();
    }
    println!("\nexpected shape (paper Fig. 3): tsenor << 2approx < binm/max1000,");
    println!("and tsenor well below entropy-with-simple-rounding.");
}
