//! Quickstart: generate transposable N:M masks for a weight matrix with
//! TSENOR through the `MaskOracle` API, verify feasibility, and compare
//! against the exact optimum.
//!
//!   cargo run --release --example quickstart
//!
//! The oracle trait is the one integration point every pruning framework
//! uses: `CpuOracle` wraps any CPU solver method, and `XlaSolver` (the
//! AOT/PJRT path, exercised below when `make artifacts` has run) plugs in
//! behind the same call. Model-level runs build a `spec::PruneSpec` on
//! top — see examples/spec_mixed.json and rust/README.md.

use tsenor::coordinator::batcher::XlaSolver;
use tsenor::data::workload;
use tsenor::masks::solver::{Method, SolveCfg};
use tsenor::masks::{self, NmPattern};
use tsenor::pruning::{CpuOracle, MaskOracle};
use tsenor::runtime::{Engine, Manifest};
use tsenor::util::tensor::partition_blocks;

fn main() -> anyhow::Result<()> {
    let pattern = NmPattern::new(8, 16);
    let w = workload::structured_matrix(256, 512, 42);
    println!("TSENOR quickstart: {}x{} matrix, transposable {pattern} sparsity", w.rows, w.cols);

    // 1. CPU oracle: entropy-regularized Dykstra + greedy/local-search
    //    rounding behind the `MaskOracle` trait.
    let oracle = CpuOracle::new(Method::Tsenor, SolveCfg::default());
    let t0 = std::time::Instant::now();
    let mask = oracle.mask(&w, pattern)?;
    let cpu_secs = t0.elapsed().as_secs_f64();

    let blocks_w = partition_blocks(&w.abs(), pattern.m);
    let blocks_m = partition_blocks(&mask, pattern.m);
    assert!(masks::batch_feasible(&blocks_m, pattern.n), "mask must be transposable");
    let obj = masks::batch_objective(&blocks_m, &blocks_w);
    let (_, opt) = masks::exact::solve_batch(&blocks_w, pattern.n);
    println!(
        "  cpu : {:.3}s  objective {:.1} / optimal {:.1}  (rel err {:.3}%)  [{} blocks solved]",
        cpu_secs,
        obj,
        opt,
        100.0 * masks::relative_error(opt, obj),
        oracle.stats().blocks_solved
    );

    // 2. XLA oracle (if artifacts are built): Algorithm 1 runs in the AOT
    //    HLO compiled from the Pallas kernel; rounding stays in Rust. Same
    //    trait, different backend.
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() {
        let manifest = Manifest::load(root)?;
        let engine = Engine::new(&manifest)?;
        let xla = XlaSolver::new(&engine, &manifest, SolveCfg::default());
        let t0 = std::time::Instant::now();
        let mask2 = xla.mask(&w, pattern)?;
        let xla_secs = t0.elapsed().as_secs_f64();
        let blocks2 = partition_blocks(&mask2, pattern.m);
        let obj2 = masks::batch_objective(&blocks2, &blocks_w);
        println!(
            "  xla : {:.3}s  objective {:.1}  ({} PJRT calls, platform {})",
            xla_secs,
            obj2,
            engine.stats().exec_calls,
            engine.platform()
        );
        assert!((obj - obj2).abs() / obj.abs() < 5e-3, "CPU and XLA paths disagree");
        println!("  cpu and xla paths agree.");
    } else {
        println!("  (run `make artifacts` to also exercise the XLA/PJRT path)");
    }

    // 3. Transposability in action: the mask stays N:M under transposition.
    let mask_t = mask.transpose();
    let blocks_t = partition_blocks(&mask_t, pattern.m);
    assert!(masks::batch_feasible(&blocks_t, pattern.n));
    println!("  transposed mask is still {pattern}-feasible — both GEMM passes accelerate.");
    Ok(())
}
