//! Quickstart: generate transposable N:M masks for a weight matrix with
//! TSENOR, verify feasibility, and compare against the exact optimum.
//!
//!   cargo run --release --example quickstart
//!
//! Uses the pure-CPU solver; if the AOT artifact bundle exists (`make
//! artifacts`), also runs the XLA/PJRT path and cross-checks the two.

use tsenor::coordinator::batcher::XlaSolver;
use tsenor::data::workload;
use tsenor::masks::solver::{self, Method, SolveCfg};
use tsenor::masks::{self, NmPattern};
use tsenor::runtime::{Engine, Manifest};
use tsenor::util::tensor::partition_blocks;

fn main() -> anyhow::Result<()> {
    let pattern = NmPattern::new(8, 16);
    let w = workload::structured_matrix(256, 512, 42);
    println!("TSENOR quickstart: {}x{} matrix, transposable {pattern} sparsity", w.rows, w.cols);

    // 1. CPU path: entropy-regularized Dykstra + greedy/local-search rounding.
    let cfg = SolveCfg::default();
    let t0 = std::time::Instant::now();
    let mask = solver::solve_matrix(Method::Tsenor, &w, pattern, &cfg);
    let cpu_secs = t0.elapsed().as_secs_f64();

    let blocks_w = partition_blocks(&w.abs(), pattern.m);
    let blocks_m = partition_blocks(&mask, pattern.m);
    assert!(masks::batch_feasible(&blocks_m, pattern.n), "mask must be transposable");
    let obj = masks::batch_objective(&blocks_m, &blocks_w);
    let (_, opt) = masks::exact::solve_batch(&blocks_w, pattern.n);
    println!(
        "  cpu : {:.3}s  objective {:.1} / optimal {:.1}  (rel err {:.3}%)",
        cpu_secs,
        obj,
        opt,
        100.0 * masks::relative_error(opt, obj)
    );

    // 2. XLA path (if artifacts are built): Algorithm 1 runs in the AOT
    //    HLO compiled from the Pallas kernel; rounding stays in Rust.
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() {
        let manifest = Manifest::load(root)?;
        let engine = Engine::new(&manifest)?;
        let xla = XlaSolver::new(&engine, &manifest, cfg);
        let t0 = std::time::Instant::now();
        let mask2 = xla.solve_matrix(&w, pattern)?;
        let xla_secs = t0.elapsed().as_secs_f64();
        let blocks2 = partition_blocks(&mask2, pattern.m);
        let obj2 = masks::batch_objective(&blocks2, &blocks_w);
        println!(
            "  xla : {:.3}s  objective {:.1}  ({} PJRT calls, platform {})",
            xla_secs,
            obj2,
            engine.exec_calls.get(),
            engine.platform()
        );
        assert!((obj - obj2).abs() / obj.abs() < 5e-3, "CPU and XLA paths disagree");
        println!("  cpu and xla paths agree.");
    } else {
        println!("  (run `make artifacts` to also exercise the XLA/PJRT path)");
    }

    // 3. Transposability in action: the mask stays N:M under transposition.
    let mask_t = mask.transpose();
    let blocks_t = partition_blocks(&mask_t, pattern.m);
    assert!(masks::batch_feasible(&blocks_t, pattern.n));
    println!("  transposed mask is still {pattern}-feasible — both GEMM passes accelerate.");
    Ok(())
}
