//! Fig. 5 driver: prune with TSENOR+ALPS, then fine-tune the transposable
//! sparse model — gradients flow through the L1 masked-GEMM kernel's VJP
//! (exact gradients on the sparse support), optimizer state lives in Rust.
//!
//!   make artifacts && cargo run --release --example finetune_sparse [steps]
//!
//! Prints the loss curve and before/after perplexity. Compare with the
//! Bi-NM retraining row printed by the fig4_speedup bench.

use tsenor::coordinator::metrics::Metrics;
use tsenor::coordinator::pipeline;
use tsenor::masks::solver::{Method, SolveCfg};
use tsenor::model::finetune::{self, FinetuneCfg};
use tsenor::pruning::CpuOracle;
use tsenor::runtime::client::ModelRuntime;
use tsenor::runtime::{Engine, Manifest};
use tsenor::spec::{Framework, PruneSpec, Structure};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let root = std::path::Path::new("artifacts");
    anyhow::ensure!(root.join("manifest.json").exists(), "run `make artifacts` first");
    let manifest = Manifest::load(root)?;
    let engine = Engine::new(&manifest)?;
    let rt = ModelRuntime::new(&engine, &manifest);

    // One spec per arm; the oracle is shared.
    let spec = PruneSpec::new(Framework::Alps)
        .pattern(16, 32)
        .calib_batches(8)
        .eval_batches(Some(8));
    let pattern = spec.pattern;
    let oracle = CpuOracle::new(Method::Tsenor, SolveCfg::default());

    println!("=== masked fine-tuning of a TSENOR+ALPS {pattern} model ({steps} steps) ===");
    let mut metrics = Metrics::new();
    let report = pipeline::run(&rt, &spec, &oracle, &mut metrics)?;
    let ppl_before = report.perplexity.clone();
    let mut state = report.state;

    let train = manifest.load_corpus("train")?;
    let cfg = FinetuneCfg { steps, ..Default::default() };
    let t0 = std::time::Instant::now();
    let curve = finetune::finetune(&rt, &mut state, &train, &cfg)?;
    let ft_secs = t0.elapsed().as_secs_f64();

    println!("\nloss curve ({:.2}s total, {:.2}s/step):", ft_secs, ft_secs / steps as f64);
    for (i, chunk) in curve.chunks(8).enumerate() {
        let row: Vec<String> = chunk.iter().map(|l| format!("{l:.4}")).collect();
        println!("  steps {:>3}+: {}", i * 8, row.join("  "));
    }

    // Sparsity must be exactly preserved by the masked optimizer.
    println!("\nsparsity after fine-tune: {:.4} (must stay 0.5)", state.sparsity());
    for (name, mask) in &state.masks {
        let w = &state.weights[name];
        for (wv, mv) in w.data.iter().zip(&mask.data) {
            assert!(*mv != 0.0 || *wv == 0.0, "support violated in {name}");
        }
    }

    let ppl_after = tsenor::eval::perplexity::perplexity_suite(&rt, &state.weights, Some(8))?;

    // --- Fig. 5 comparator: standard N:M pruning + fine-tuning, the
    // idealized stand-in for Bi-NM retraining (Bi-NM trains a standard
    // N:M network with gradients APPROXIMATED through a transposable
    // mask; our comparator gives it exact gradients, an upper bound —
    // see EXPERIMENTS.md §Fig5).
    println!("\n--- comparator: standard N:M (ALPS) + fine-tune ---");
    let spec_std = spec.clone().structure(Structure::StandardNm);
    let mut metrics2 = Metrics::new();
    let report_std = pipeline::run(&rt, &spec_std, &oracle, &mut metrics2)?;
    let mut state_std = report_std.state;
    let curve_std = finetune::finetune(&rt, &mut state_std, &train, &cfg)?;
    println!(
        "  std-N:M fine-tune loss {:.4} -> {:.4}",
        curve_std.first().unwrap_or(&f32::NAN),
        curve_std.last().unwrap_or(&f32::NAN)
    );
    let ppl_std = tsenor::eval::perplexity::perplexity_suite(&rt, &state_std.weights, Some(8))?;

    println!(
        "\n{:<16}{:>12}{:>14}{:>18}",
        "corpus", "pruned", "tsenor+ft", "std-N:M+ft"
    );
    for (name, before) in &ppl_before {
        println!(
            "{:<16}{:>12.3}{:>14.3}{:>18.3}",
            name,
            before,
            ppl_after.get(name).unwrap_or(&f64::NAN),
            ppl_std.get(name).unwrap_or(&f64::NAN)
        );
    }
    println!("\nFig. 5 reading: at M=32 the transposable model fine-tunes to parity");
    println!("with the standard-N:M model while ALSO accelerating the backward pass.");
    Ok(())
}
